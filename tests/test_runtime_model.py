"""Runtime model §IV-A: distributions, expectations, order statistics."""
import numpy as np
import pytest

from repro.core.runtime_model import (
    ClusterParams,
    expected_max_exponential,
    expected_max_geometric,
    kth_min,
    paper_cluster,
)
from repro.core.topology import Topology


def test_kth_min():
    v = np.array([3.0, 4.0, 5.0, 6.0])
    assert kth_min(v, 3) == 5.0  # paper's example: min_{3-th}{3,4,5,6} = 5
    assert kth_min(v, 1) == 3.0
    assert kth_min(v, 4) == 6.0
    m = np.array([[3.0, 1.0, 2.0], [9.0, 7.0, 8.0]])
    np.testing.assert_array_equal(kth_min(m, 2, axis=1), [2.0, 8.0])


def test_sampled_expectations_match_model():
    """Monte-Carlo means match E[T] = cD + 1/γ + 2τ_w/(1−p_w) + τ_e/(1−p_e)."""
    params = ClusterParams.homogeneous(
        Topology.uniform(2, 3), c=10.0, gamma=0.1, tau_w=50.0, p_w=0.2,
        tau_e=100.0, p_e=0.1,
    )
    rng = np.random.default_rng(0)
    D = 4.0
    tot = np.zeros(params.topo.total_workers)
    ups = np.zeros(params.topo.n)
    N = 20000
    for _ in range(N):
        wt, eu, _ = params.sample_iteration(rng, D)
        tot += wt
        ups += eu
    emp = tot / N
    model = params.expected_worker_total(D)
    np.testing.assert_allclose(emp, model, rtol=0.03)
    np.testing.assert_allclose(
        ups / N, params.expected_edge_upload(), rtol=0.03
    )


def test_variance_matches_model():
    params = ClusterParams.homogeneous(
        Topology.uniform(1, 2), c=5.0, gamma=0.05, tau_w=40.0, p_w=0.3,
        tau_e=80.0, p_e=0.15,
    )
    rng = np.random.default_rng(1)
    xs = np.stack(
        [params.sample_iteration(rng, 2.0)[0] for _ in range(30000)]
    )
    np.testing.assert_allclose(
        xs.var(axis=0), params.worker_total_variance(), rtol=0.06
    )


def test_geometric_distribution_definition():
    """Pr(N = x) = p^{x−1}(1−p): mean must be 1/(1−p)."""
    rng = np.random.default_rng(2)
    p = 0.4
    n = rng.geometric(1.0 - p, size=200000)
    assert np.mean(n) == pytest.approx(1.0 / (1.0 - p), rel=0.02)


def test_expected_max_approximations():
    """The paper's §IV-B approximations are close to Monte Carlo."""
    rng = np.random.default_rng(3)
    gamma, k = 0.1, 30
    mc = np.max(rng.exponential(1 / gamma, size=(20000, k)), axis=1).mean()
    assert expected_max_exponential(gamma, k) == pytest.approx(mc, rel=0.15)
    p, k = 0.2, 10
    mc = np.max(
        rng.geometric(1 - p, size=(20000, k)), axis=1
    ).mean()
    assert expected_max_geometric(p, k) == pytest.approx(mc, rel=0.25)


def test_paper_cluster_composition():
    params = paper_cluster("mnist")
    assert params.topo.n == 4 and params.topo.total_workers == 40
    # edge types: 1 strong + 2 normal + 1 weak
    assert sorted(params.tau_e.tolist()) == [50.0, 100.0, 100.0, 500.0]
    # per edge: 7 strong-compute (c=10), 3 weak-compute (c=50)
    c0 = params.c[:10]
    assert (c0 == 10.0).sum() == 7 and (c0 == 50.0).sum() == 3
    cifar = paper_cluster("cifar")
    assert set(np.unique(cifar.c)) == {100.0, 500.0}


def test_shape_validation():
    topo = Topology.uniform(2, 2)
    with pytest.raises(ValueError):
        ClusterParams(
            topo=topo,
            c=np.ones(3),  # wrong: W = 4
            gamma=np.ones(4),
            tau_w=np.ones(4),
            p_w=np.ones(4) * 0.1,
            tau_e=np.ones(2),
            p_e=np.ones(2) * 0.1,
        )
