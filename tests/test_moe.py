"""MoE: sort-based dispatch vs dense oracle, capacity, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: fixed-example fallback
    from repro._hypothesis_fallback import (
        given, settings, strategies as st,
    )

from repro.models import moe as M


def _setup(seed, d=16, ff=8, E=4, n_shared=0):
    rng = jax.random.PRNGKey(seed)
    p = M.init_moe(rng, d, ff, E, n_shared, jnp.float32)
    return p


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 50),
    top_k=st.sampled_from([1, 2]),
    B=st.integers(1, 2),
    Sq=st.sampled_from([4, 8]),
)
def test_dispatch_matches_dense_oracle(seed, top_k, B, Sq):
    p = _setup(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, Sq, 16)) * 0.5
    # capacity_factor big enough that nothing is dropped
    out, aux = M.moe_ffn(p, x, top_k=top_k, capacity_factor=8.0)
    ref = M.moe_ffn_reference(p, x, top_k=top_k)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    assert jnp.isfinite(aux)


def test_shared_expert_path():
    p = _setup(3, n_shared=1)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 16)) * 0.5
    out, _ = M.moe_ffn(p, x, top_k=1, capacity_factor=8.0)
    ref = M.moe_ffn_reference(p, x, top_k=1)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


def test_capacity_drops_tokens():
    """With capacity_factor ≪ 1 some tokens must be dropped (≠ oracle)."""
    p = _setup(5)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 32, 16)) * 0.5
    out_tight, _ = M.moe_ffn(p, x, top_k=2, capacity_factor=0.25)
    ref = M.moe_ffn_reference(p, x, top_k=2)
    assert not np.allclose(out_tight, ref, rtol=1e-4, atol=1e-5)
    assert jnp.all(jnp.isfinite(out_tight))


def test_aux_loss_balanced_routing_is_minimal():
    """Uniform routing gives aux ≈ 1 (its minimum); skewed routing > 1."""
    E, d = 4, 8
    p = _setup(7, d=d, E=E)
    # force uniform logits → perfectly balanced expectation
    p = dict(p)
    p["router"] = jnp.zeros((d, E))
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 64, d))
    _, aux_uniform = M.moe_ffn(p, x, top_k=1, capacity_factor=8.0)
    assert float(aux_uniform) == pytest.approx(1.0, abs=0.15)
    # heavily skewed router
    p["router"] = jnp.zeros((d, E)).at[:, 0].set(10.0)
    x0 = jnp.ones((1, 64, d))
    _, aux_skew = M.moe_ffn(p, x0, top_k=1, capacity_factor=8.0)
    assert float(aux_skew) > float(aux_uniform) * 1.5


def test_moe_gradients_finite():
    p = _setup(9)
    x = jax.random.normal(jax.random.PRNGKey(10), (1, 8, 16)) * 0.5

    def loss(p_):
        y, aux = M.moe_ffn(p_, x, top_k=2, capacity_factor=2.0)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert jnp.all(jnp.isfinite(leaf))
