"""Flash attention: custom-VJP (pure JAX) and the Pallas kernel
vs dense-attention autodiff oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: fixed-example fallback
    from repro._hypothesis_fallback import (
        given, settings, strategies as st,
    )

from repro.kernels.flash_attention import flash_attention_gqa
from repro.models import attention as A


def _setup(seed, B, S, Kv, G, Dh):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, S, Kv * G, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kv, Dh), jnp.float32)
    do = jax.random.normal(ks[3], (B, S, Kv * G, Dh), jnp.float32)
    return q, k, v, do


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 100),
    B=st.integers(1, 2),
    S=st.sampled_from([32, 64]),
    Kv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 2]),
    causal=st.booleans(),
    window=st.sampled_from([0, 16]),
    softcap=st.sampled_from([0.0, 20.0]),
)
def test_flash_cvjp_fwd_bwd_vs_dense(seed, B, S, Kv, G, causal, window,
                                     softcap):
    if not causal and window:
        window = 0
    Dh = 8
    q, k, v, do = _setup(seed, B, S, Kv, G, Dh)
    pos = jnp.arange(S)

    def dense(q, k, v):
        return A.dense_attention(q, k, v, pos[None], pos[None],
                                 causal=causal, window=window,
                                 softcap=softcap)

    def flash(q, k, v):
        return A.flash_attention(q, k, v, causal, window, softcap, 16, 0)

    od, vjp_d = jax.vjp(dense, q, k, v)
    of, vjp_f = jax.vjp(flash, q, k, v)
    np.testing.assert_allclose(od, of, rtol=2e-5, atol=2e-5)
    for a, b in zip(vjp_d(do), vjp_f(do)):
        np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-5)


def test_flash_cvjp_q_chunked():
    q, k, v, do = _setup(7, 1, 64, 2, 2, 16)
    pos = jnp.arange(64)
    dense = A.dense_attention(q, k, v, pos[None], pos[None], causal=True)
    flash = A.flash_attention(q, k, v, True, 0, 0.0, 16, 16)
    np.testing.assert_allclose(dense, flash, rtol=2e-5, atol=2e-5)
    gd = jax.grad(lambda q_: jnp.sum(
        A.dense_attention(q_, k, v, pos[None], pos[None], causal=True)**2
    ))(q)
    gf = jax.grad(lambda q_: jnp.sum(
        A.flash_attention(q_, k, v, True, 0, 0.0, 16, 16)**2
    ))(q)
    np.testing.assert_allclose(gd, gf, rtol=5e-5, atol=5e-5)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 50),
    B=st.integers(1, 2),
    S=st.sampled_from([64, 128]),
    Kv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 2]),
    causal=st.booleans(),
    window=st.sampled_from([0, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_pallas_kernel_vs_dense(seed, B, S, Kv, G, causal, window, dtype):
    if not causal and window:
        window = 0
    Dh = 128  # lane-aligned as on TPU
    q, k, v, _ = _setup(seed, B, S, Kv, G, Dh)
    q, k, v = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    pos = jnp.arange(S)
    want = A.dense_attention(q, k, v, pos[None], pos[None],
                             causal=causal, window=window)
    got = flash_attention_gqa(q, k, v, causal=causal, window=window,
                              interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(want, np.float32), np.asarray(got, np.float32),
        rtol=tol, atol=tol,
    )


def test_model_flash_flag_equivalence():
    """forward(flash=True) ≡ forward(flash=False) on a smoke config."""
    import dataclasses

    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as tf

    cfg0 = dataclasses.replace(
        get_smoke_config("llama3-8b"), dtype="float32")
    cfg1 = dataclasses.replace(cfg0, flash=True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg0)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg0.vocab)
    l0, _ = tf.forward(params, cfg0, tokens)
    l1, _ = tf.forward(params, cfg1, tokens)
    np.testing.assert_allclose(l0, l1, rtol=1e-4, atol=1e-4)
    g0 = jax.grad(lambda p: tf.loss_and_metrics(
        p, cfg0, {"tokens": tokens, "targets": tokens})[0])(params)
    g1 = jax.grad(lambda p: tf.loss_and_metrics(
        p, cfg1, {"tokens": tokens, "targets": tokens})[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-5)
