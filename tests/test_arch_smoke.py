"""Per-architecture smoke tests (assignment requirement):

Instantiate the REDUCED config of each assigned family, run one forward
and one train step on CPU, assert output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as tf


def _batch(cfg, B=2, S=32, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {
        "tokens": tokens,
        "targets": jnp.roll(tokens, -1, axis=1),
        "weights": jnp.ones((B, S)),
    }
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            rng, (B, cfg.enc_len, cfg.d_model)
        )
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch["positions"] = jnp.broadcast_to(pos, (3, B, S))
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(42)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_smoke_config(arch)
    params = tf.init_params(rng, cfg)
    batch = _batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: tf.forward(
            p, cfg, b["tokens"], positions=b.get("positions"),
            enc_frames=b.get("enc_frames"),
        )
    )(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch, rng):
    """SGD step: loss decreases-or-equal and params stay finite."""
    cfg = get_smoke_config(arch)
    params = tf.init_params(rng, cfg)
    batch = _batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p_: tf.loss_and_metrics(p_, cfg, b), has_aux=True
        )(p)
        new_p = jax.tree.map(lambda a, g: a - 0.05 * g, p, grads)
        return loss, new_p

    loss0, params = step(params, batch)
    loss1, params = step(params, batch)
    assert bool(jnp.isfinite(loss0)) and bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0) + 0.05  # moving downhill
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected
    if arch == "mamba2-370m":
        assert cfg.d_state == 128 and cfg.family == "ssm"
    if arch == "granite-moe-3b-a800m":
        assert (cfg.n_experts, cfg.top_k) == (40, 8)
    if arch == "llama4-maverick-400b-a17b":
        assert (cfg.n_experts, cfg.top_k) == (128, 1)
    if arch == "gemma3-27b":
        assert cfg.block_pattern.count("local") == 5
        assert cfg.block_pattern.count("global") == 1
    if arch == "recurrentgemma-2b":
        assert cfg.block_pattern.count("recurrent") == 2


def test_param_count_sanity():
    """Full-config parameter counts are in the advertised ballpark."""
    ranges = {
        "llama3-8b": (7e9, 9e9),
        "granite-8b": (7.5e9, 9.5e9),
        "starcoder2-3b": (2.5e9, 3.8e9),
        "gemma3-27b": (24e9, 30e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
        "mamba2-370m": (3e8, 5e8),
        "llama4-maverick-400b-a17b": (3.4e11, 4.6e11),
    }
    for arch, (lo, hi) in ranges.items():
        total, active = get_config(arch).param_counts()
        assert lo <= total <= hi, (arch, total)
        assert 0 < active <= total


def test_moe_active_params_much_smaller():
    total, active = get_config("llama4-maverick-400b-a17b").param_counts()
    assert active < total / 5  # a17b of 400b


def test_shape_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
