"""Orchestrator units: registry state machine, heartbeat deadlines,
injector grammar, worker pool, metrics schema, and the structured
ReplanError surface (ISSUE satellites 1 and 2).

Episode-level behaviour (real pool + real session) lives in
``test_orchestrator_episode.py``; everything here is fast and mostly
numpy-only.
"""
import json
import os

import numpy as np
import pytest

from repro.core.topology import Topology
from repro.orchestrator import events as ev_mod
from repro.orchestrator.events import Event, EventLog
from repro.orchestrator.heartbeat import (Heartbeat, HeartbeatConfig,
                                          HeartbeatMonitor)
from repro.orchestrator.injector import (FailureInjector, Injection,
                                         InjectionSchedule)
from repro.orchestrator.metrics import (COUNTERS,
                                        METRICS_SCHEMA_VERSION,
                                        MetricsSink, read_metrics)
from repro.orchestrator.registry import (DEAD, HEALTHY, JOINING, SUSPECT,
                                         DeviceRegistry)
from repro.orchestrator.workers import (ModelRow, WorkerPool, WorkItem,
                                        draw_runtime_ms,
                                        probe_part_vector,
                                        probe_true_sum,
                                        resolve_backend)


def _registry(m=(2, 2)):
    reg = DeviceRegistry(Topology(m))
    reg.register_all()
    return reg


# ----------------------------------------------------------------------
# registry — the liveness state machine
# ----------------------------------------------------------------------
def test_registry_lifecycle_and_events():
    reg = _registry()
    assert reg.counts() == {JOINING: 4, HEALTHY: 0, SUSPECT: 0, DEAD: 0}
    for f in range(4):
        reg.beat(f, step=0, clock_ms=10.0)
    assert reg.counts()[HEALTHY] == 4
    assert [e.kind for e in reg.log.events] == [ev_mod.WORKER_JOINED] * 4

    # miss budget: first miss -> SUSPECT, third -> DEAD
    reg.miss(0, step=1, clock_ms=500.0, suspect_after=1, dead_after=3)
    assert reg.state_of(0) == SUSPECT
    assert reg.record(0).live  # SUSPECT may still submit
    for k in range(2):
        reg.miss(0, step=2 + k, clock_ms=600.0 + k, suspect_after=1,
                 dead_after=3)
    assert reg.state_of(0) == DEAD
    assert not reg.record(0).live
    assert reg.record(0).deaths == 1
    assert reg.dead_workers() == [0]
    assert reg.live_workers() == [1, 2, 3]

    # a beat heals: DEAD -> HEALTHY is a rejoin, SUSPECT -> HEALTHY a
    # recovery — distinct event kinds
    reg.miss(1, step=4, clock_ms=700.0, suspect_after=1, dead_after=3)
    reg.beat(1, step=5, clock_ms=800.0)
    reg.beat(0, step=5, clock_ms=800.0)
    kinds = [e.kind for e in reg.log.events]
    assert ev_mod.WORKER_RECOVERED in kinds
    assert ev_mod.WORKER_REJOINED in kinds
    assert reg.counts() == {JOINING: 0, HEALTHY: 4, SUSPECT: 0, DEAD: 0}
    # miss counters reset on the beat
    assert reg.record(0).consecutive_misses == 0


def test_registry_illegal_transition_raises():
    reg = _registry()
    # a worker that never beat takes JOINING -> SUSPECT -> DEAD once
    # its join grace expires (a kill before the first report must be
    # detectable)
    reg.miss(0, step=0, clock_ms=100.0, suspect_after=1, dead_after=2)
    assert reg.state_of(0) == SUSPECT
    reg.miss(0, step=1, clock_ms=200.0, suspect_after=1, dead_after=2)
    assert reg.state_of(0) == DEAD
    # JOINING -> DEAD without passing SUSPECT is illegal
    with pytest.raises(ValueError, match="illegal liveness transition"):
        reg._transition(reg.record(1), DEAD, 0, 0.0, ev_mod.WORKER_DEAD)
    with pytest.raises(ValueError, match="already registered"):
        reg.register(0, 0)


def test_registry_edge_down_is_derived():
    reg = _registry((2, 3))
    for f in range(5):
        reg.beat(f, step=0, clock_ms=1.0)
    # kill all of edge 0 (workers 0, 1): edge_down fires exactly once,
    # on the LAST worker's death
    for f in (0, 1):
        for k in range(3):
            reg.miss(f, step=k, clock_ms=10.0 * k, suspect_after=1,
                     dead_after=3)
    assert reg.edge_down(0) and reg.down_edges() == [0]
    assert len(reg.log.of_kind(ev_mod.EDGE_DOWN)) == 1
    # one rejoin heals the pod
    reg.beat(0, step=9, clock_ms=500.0)
    assert not reg.edge_down(0)
    assert len(reg.log.of_kind(ev_mod.EDGE_UP)) == 1


# ----------------------------------------------------------------------
# heartbeat — deadlines, backoff, the observation ledger
# ----------------------------------------------------------------------
def test_heartbeat_config_validation():
    with pytest.raises(ValueError, match="below interval"):
        HeartbeatConfig(interval_ms=100, timeout_ms=50)
    with pytest.raises(ValueError, match="backoff"):
        HeartbeatConfig(backoff=0.5)
    with pytest.raises(ValueError, match="suspect_after"):
        HeartbeatConfig(suspect_after=3, dead_after=1)


def test_monitor_flap_and_backoff():
    reg = _registry()
    mon = HeartbeatMonitor(reg, HeartbeatConfig(
        interval_ms=100, timeout_ms=100, backoff=2.0,
        suspect_after=1, dead_after=3))
    for f in range(4):
        mon.deliver(Heartbeat(f, sent_ms=0.0, runtime_ms=200.0), step=0)
    # worker 0 goes silent: first tick past the deadline charges a miss
    for f in range(1, 4):
        mon.deliver(Heartbeat(f, sent_ms=150.0, runtime_ms=210.0), step=1)
    assert mon.tick(1, now_ms=150.0) == 1
    assert reg.state_of(0) == SUSPECT
    # backoff: the NEXT deadline for worker 0 is 100 * 2^1 = 200 ms
    # after its last beat — a tick at 260 misses again, one at 190 not
    assert mon.tick(1, now_ms=190.0) == 0
    # ...but the flap: the late beat lands before the next deadline
    mon.deliver(Heartbeat(0, sent_ms=195.0, runtime_ms=400.0), step=2)
    assert reg.state_of(0) == HEALTHY
    assert reg.record(0).consecutive_misses == 0
    assert mon.beats_total == 8
    assert mon.misses_total == 1


def test_monitor_ledger_fills_silent_workers():
    reg = _registry()
    mon = HeartbeatMonitor(reg, HeartbeatConfig(miss_fill_factor=2.0))
    row = mon.record_round({0: 100.0, 1: 120.0, 2: 80.0})  # 3 silent
    assert row.shape == (4,)
    # no history: silent worker filled from the round's slowest
    assert row[3] == pytest.approx(2.0 * 120.0)
    row2 = mon.record_round({0: 100.0, 1: 120.0, 2: 80.0})
    # with history: filled from its own EWMA (of the previous fill)
    assert row2[3] == pytest.approx(2.0 * row[3])
    obs = mon.observation_matrix()
    assert obs.shape == (2, 4)
    assert mon.observation_matrix(window=1).shape == (1, 4)


def test_monitor_fit_cluster_prices_observed_slowness():
    from repro.api.cluster import CodedCluster

    topo = Topology((2, 2))
    reg = DeviceRegistry(topo)
    reg.register_all()
    mon = HeartbeatMonitor(reg)
    rng = np.random.default_rng(0)
    for _ in range(8):
        base = rng.uniform(90, 110, size=4)
        base[3] *= 5.0  # worker 3 is consistently 5x slower
        mon.record_round({f: float(base[f]) for f in range(4)})
    with pytest.raises(ValueError, match="no observation rows"):
        HeartbeatMonitor(DeviceRegistry(topo)).fit_cluster(4.0)
    fitted = mon.fit_cluster(D=4.0)
    assert isinstance(fitted, CodedCluster)
    assert fitted.topo == topo
    assert fitted.params.c[3] > 3.0 * fitted.params.c[0]


# ----------------------------------------------------------------------
# injector — grammar, determinism, windows
# ----------------------------------------------------------------------
def test_injection_spec_roundtrip_and_errors():
    sched = InjectionSchedule.parse(
        "kill:w0.1@3, slow:e1@5x3:4.0, partition:w1.0@2x2")
    assert len(sched) == 3
    assert InjectionSchedule.parse(sched.spec()).spec() == sched.spec()
    kill = [x for x in sched.injections if x.kind == "kill"][0]
    assert (kill.edge, kill.worker, kill.step) == (0, 1, 3)
    slow = [x for x in sched.injections if x.kind == "slow"][0]
    assert slow.worker is None and slow.duration == 3 and slow.factor == 4.0

    for bad in ("explode:w0.1@3", "kill:w0@3", "kill:x0.1@3",
                "slow:e1@5x3:0.5", "kill:w0.1"):
        with pytest.raises(ValueError):
            InjectionSchedule.parse(bad)


def test_injection_windows_and_targets():
    topo = Topology((2, 3))
    inj = Injection(kind="slow", step=5, edge=1, worker=None,
                    duration=3, factor=2.0)
    assert [inj.active(s) for s in (4, 5, 7, 8)] == [False, True, True,
                                                     False]
    assert inj.targets(topo) == (2, 3, 4)
    kill = Injection(kind="kill", step=3, edge=0, worker=1)
    assert kill.active(3) and kill.active(100) and not kill.active(2)
    assert kill.targets(topo) == (1,)

    fi = FailureInjector(InjectionSchedule([inj, kill]), topo)
    eff = fi.effects(5)
    assert eff.killed == {1} and eff.slow_factor(3) == 2.0
    assert eff.slow_factor(0) == 1.0
    assert [x.kind for x in eff.started] == ["slow"]
    assert fi.effects(8).slow == {}
    assert fi.applied == 1  # only the slow START landed in [5, 8]


def test_seeded_schedule_deterministic_and_capped():
    topo = Topology((3, 3))
    a = InjectionSchedule.seeded(7, topo, steps=20, n_events=6)
    b = InjectionSchedule.seeded(7, topo, steps=20, n_events=6)
    assert a.spec() == b.spec()
    assert a.spec() != InjectionSchedule.seeded(8, topo, 20,
                                                n_events=6).spec()
    kills = [x for x in a.injections if x.kind == "kill"]
    assert len(kills) <= 1
    assert all(x.worker is not None for x in kills)  # never a whole pod


# ----------------------------------------------------------------------
# workers — determinism and the probe algebra
# ----------------------------------------------------------------------
def test_runtime_draw_deterministic():
    row = ModelRow(c=10, gamma=0.05, tau_w=20, p_w=0.1, tau_e=30,
                   p_e=0.1)
    a = draw_runtime_ms(row, flat=2, step=5, seed=3, D=4.0)
    assert a == draw_runtime_ms(row, flat=2, step=5, seed=3, D=4.0)
    assert a != draw_runtime_ms(row, flat=2, step=6, seed=3, D=4.0)
    slow = draw_runtime_ms(row, flat=2, step=5, seed=3, D=4.0,
                           slow_factor=4.0)
    assert slow == pytest.approx(a + 3.0 * 10 * 4.0)  # scales c*D only


def test_probe_partials_decode_through_lambda():
    """The pool's probe computation IS eq. (22): master-side λ-decode
    of the per-worker partials recovers Σ_k s_k exactly."""
    from repro.core.hgc import HGCCode
    from repro.core.topology import Tolerance

    topo = Topology((3, 3, 3))
    code = HGCCode.build(topo, Tolerance(1, 1), K=9)
    dim, probe_seed = 16, 1234
    partials = {}
    for i in range(3):
        for j in range(3):
            coeffs = code.worker_coeffs(i, j)
            p = np.zeros(dim)
            for k in code.assignment.worker_parts(i, j):
                p += coeffs[k] * probe_part_vector(probe_seed, k, dim)
            partials[topo.flat_index(i, j)] = p
    # drop edge 2 and one worker per surviving edge
    fast_e, fast_w = (0, 1), [(0, 2), (1, 2), ()]
    lam = code.collapsed_weights(fast_e, fast_w)
    decoded = sum(lam[f] * partials[f] for f in partials if lam[f] != 0)
    np.testing.assert_allclose(
        decoded, probe_true_sum(probe_seed, code.K, dim),
        rtol=1e-8, atol=1e-9)


def test_worker_pool_thread_backend_kill_and_stale_drop():
    topo = Topology((1, 2))
    rows = [ModelRow(c=5, gamma=0.1, tau_w=5, p_w=0.1, tau_e=5,
                     p_e=0.1)] * 3
    assert resolve_backend("auto") in ("process", "thread")
    with pytest.raises(ValueError, match="unknown worker backend"):
        resolve_backend("fiber")
    with WorkerPool(topo, rows, seed=0, backend="thread") as pool:
        work = lambda s: WorkItem(step=s, clock_ms=0.0,
                                  coeffs=np.ones(3), parts=(0,),
                                  D=1.0, probe_seed=1)
        for f in range(3):
            assert pool.dispatch(f, work(0))
        res = pool.collect(0, {0, 1, 2})
        assert sorted(res) == [0, 1, 2]
        assert pool.kill(1) and not pool.kill(1)
        assert pool.alive == {0, 2}
        assert not pool.dispatch(1, work(1))
        # stale message from an old round is dropped, not returned
        pool.inject_message(("result", res[0]))
        for f in (0, 2):
            pool.dispatch(f, work(1))
        res1 = pool.collect(1, {0, 2})
        assert sorted(res1) == [0, 2]
        assert all(r.step == 1 for r in res1.values())
    with pytest.raises(ValueError, match="one ModelRow per worker"):
        WorkerPool(topo, rows[:2])


# ----------------------------------------------------------------------
# metrics — stable schema, JSONL round trip
# ----------------------------------------------------------------------
def test_metrics_schema_and_roundtrip(tmp_path):
    path = os.fspath(tmp_path / "m.jsonl")
    sink = MetricsSink(path)
    assert set(sink.counters) == set(COUNTERS)
    with pytest.raises(KeyError, match="unknown counter"):
        sink.bump("oops")
    sink.bump("replans")
    sink.bump("heartbeat_misses", 3)
    sink.iteration(
        step=0, clock_ms=123.4, loss=2.5, iter_ms=120.0,
        fast_e=(0, 1), fast_w=[(0, 1), (2,), ()], n_results=5,
        n_counted=3, straggler_hit=True, decode_ok=True,
        heartbeat_misses=1, states={"HEALTHY": 5},
        round_events=[Event(kind=ev_mod.REPLAN, step=0, clock_ms=1.0)],
        wall_us=456.7)
    sink.summary(steps=1, jit_cache_entries=1, final_loss=2.5,
                 episode_ms=123.4, detect_to_replan_ms=50.0)
    sink.close()

    m = read_metrics(path)
    assert len(m["iteration"]) == 1 and len(m["summary"]) == 1
    it = m["iteration"][0]
    assert it["schema"] == METRICS_SCHEMA_VERSION
    assert it["fast_w"] == [[0, 1], [2], []]
    assert it["events"][0]["kind"] == "replan"
    s = m["summary"][0]
    assert s["counters"]["replans"] == 1
    assert s["counters"]["heartbeat_misses"] == 3
    assert s["detect_to_replan_ms"] == 50.0

    # schema drift fails loudly
    with open(path, "a") as f:
        f.write(json.dumps({"record": "iteration", "schema": 999}) + "\n")
    with pytest.raises(ValueError, match="schema"):
        read_metrics(path)


def test_event_log_drain_windows():
    log = EventLog()
    log.append(Event(kind=ev_mod.REPLAN, step=0, clock_ms=1.0))
    assert [e.kind for e in log.drain_new()] == ["replan"]
    assert log.drain_new() == []
    log.append(Event(kind=ev_mod.SHRINK, step=1, clock_ms=2.0))
    assert [e.kind for e in log.drain_new()] == ["shrink"]
    assert log.first(ev_mod.REPLAN).step == 0
    assert log.counts() == {"replan": 1, "shrink": 1}
    with pytest.raises(ValueError, match="unknown event kind"):
        Event(kind="explosion", step=0, clock_ms=0.0)


# ----------------------------------------------------------------------
# ReplanError — the structured replan failure surface (satellites 1+2)
# ----------------------------------------------------------------------
def test_replan_error_exported_and_structured():
    from repro.api import ReplanError

    err = ReplanError("boom", constraint="uniform_load",
                      topo=Topology((2, 2)))
    assert isinstance(err, RuntimeError)
    assert err.constraint == "uniform_load"
    assert err.topo.m == (2, 2)


def test_uniform_load_rejection_names_offending_edge():
    """Satellite 2: the dist-mode rejection of a non-uniform grouped
    plan names the offending edge and its load and points at the
    planner docs."""
    from repro.api.session import CodedSession

    class FakeCode:
        loads = (4, 4, 6)

    class FakeSession:
        mode = "coded"

    with pytest.raises(ValueError) as ei:
        CodedSession._require_dist_uniform_load(FakeSession(), FakeCode())
    msg = str(ei.value)
    assert "edge 2" in msg and "D=6" in msg
    assert "D=4" in msg and "(4, 4, 6)" in msg
    assert "docs/planners.md" in msg
    # uniform-valued grouped loads pass; mode off never rejects
    FakeCode.loads = (4, 4, 4)
    CodedSession._require_dist_uniform_load(FakeSession(), FakeCode())
    FakeSession.mode = "off"
    FakeCode.loads = (4, 4, 6)
    CodedSession._require_dist_uniform_load(FakeSession(), FakeCode())
