"""Multi-device shard_map collectives (8 CPU host devices, subprocess).

Validates the explicit two-stage coded aggregation (grad_sync) on a
real (2 pods × 2 data × 2 model) device mesh — the form whose
collectives appear in the dry-run HLO.  Runs in a subprocess so the
512-device dry-run flag and the test session's single device never
conflict.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.hgc import HGCCode
    from repro.core.topology import Tolerance, Topology
    from repro.dist.grad_sync import (
        make_coded_allreduce, make_compressed_cross_pod_sum,
        lam_array_from_code,
    )
    from repro.dist.mesh import make_test_mesh

    mesh = make_test_mesh(2, 2, 2)  # pod × data × model
    topo = Topology.uniform(2, 2)   # edge=pod, worker=data group
    code = HGCCode.build(topo, Tolerance(1, 1), K=4, seed=0)

    rng = np.random.default_rng(0)
    g_parts = rng.normal(size=(code.K, 64)).astype(np.float32)
    true = g_parts.sum(0)

    # each (pod=i, data=j) group computes its encoded message G_ij
    msgs = np.stack([
        code.worker_encode(i, j, g_parts)
        for i in range(2) for j in range(2)
    ]).astype(np.float32)  # (4, 64)

    fast_e, fast_w = (0, 1), [(1,), (0,)]   # 1 straggler per edge
    lam = lam_array_from_code(code, fast_e, fast_w, 2, 2)

    # build per-group message tree replicated per group via shard_map:
    # feed each group its own message by sharding a (pods, data, dim)
    # array and reducing with the coded weights.
    from jax.sharding import PartitionSpec as P
    from repro.dist._compat import shard_map
    from repro.dist.grad_sync import coded_weighted_psum

    def inner(msg_block, lam_block):
        # msg_block: (1, 1, 64) this group's message
        return coded_weighted_psum(
            {"g": msg_block[0, 0]}, lam_block.reshape(())
        )["g"]

    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(P("pod", "data", None), P("pod", "data")),
        out_specs=P(),
        check_rep=False,
    )
    out = jax.jit(fn)(
        jnp.asarray(msgs.reshape(2, 2, 64)), jnp.asarray(lam)
    )
    err = float(np.max(np.abs(np.asarray(out) - true)))
    assert err < 1e-4, f"coded psum error {err}"
    print("coded_psum_ok", err)

    # hier allreduce == flat sum
    runner = make_coded_allreduce(mesh)
    ones_lam = np.ones((2, 2), np.float32)
    tree = {"a": jnp.ones((8, 8)) * 2.0}
    out2 = jax.jit(lambda t, l: runner(t, l))(tree, jnp.asarray(ones_lam))
    expect = 2.0 * 4  # summed over 2 pods × 2 data groups
    assert np.allclose(np.asarray(out2["a"]), expect), out2["a"][0, 0]
    print("hier_allreduce_ok")

    # compressed cross-pod path ≈ exact
    comp = make_compressed_cross_pod_sum(mesh)
    tree2 = {"a": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    got = jax.jit(lambda t, l: comp(t, l))(tree2, jnp.asarray(ones_lam))
    exact = np.asarray(tree2["a"]) * 4
    rel = np.max(np.abs(np.asarray(got["a"]) - exact)) / np.max(np.abs(exact))
    assert rel < 0.05, rel
    print("compressed_ok", rel)
    """
)


@pytest.mark.parametrize("script", [_SCRIPT], ids=["8dev"])
def test_shard_map_coded_collectives(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "coded_psum_ok" in r.stdout
    assert "hier_allreduce_ok" in r.stdout
    assert "compressed_ok" in r.stdout
