"""End-to-end system tests: the training driver as a black box —
checkpoint/restart continuity, JNCSS planning, straggler tolerance.

This suite deliberately touches NOTHING but the CLI mains (the
import-lint step enforces it); the launch-layer unit tests live in
test_launch_units.py."""
import json
import os
import subprocess
import sys



def _run_train(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_train_driver_end_to_end_with_restart(tmp_path):
    """Train 8 steps w/ checkpoints, kill, resume to 14 — losses finite,
    resume starts exactly where the checkpoint left off."""
    ck = str(tmp_path / "ckpt")
    base = [
        "--arch", "llama3-8b", "--smoke", "--scheme", "hgc",
        "--n-edges", "2", "--n-workers", "4", "--seq-len", "32",
        "--checkpoint-dir", ck, "--checkpoint-every", "4",
        "--log-every", "2", "--seed", "3",
    ]
    out1 = _run_train(base + ["--steps", "8"])
    assert "step     0" in out1
    man = json.load(open(os.path.join(ck, "manifest.json")))
    assert man["steps"][-1] == 8
    out2 = _run_train(base + ["--steps", "14", "--resume"])
    assert "resumed from step 8" in out2
    assert "step    13" in out2 or "step 13" in out2.replace("  ", " ")


def test_train_driver_jncss_scheme(tmp_path):
    out = _run_train([
        "--arch", "mamba2-370m", "--smoke", "--scheme", "hgc_jncss",
        "--n-edges", "2", "--n-workers", "4", "--seq-len", "16",
        "--steps", "4", "--log-every", "2",
    ])
    assert "JNCSS chose" in out
    assert "done: 4 steps" in out
