"""Property tests over the scheme registry (ISSUE satellite).

The contract every exact scheme advertises: under ANY straggler pattern
within its tolerance, the master's aggregate equals the exact gradient
sum Σ_k g_k.  Rather than hand-constructing outcomes, each example
draws random (possibly adversarially boosted) runtimes, lets the
scheme's own waiting rule pick the fast sets, and checks the decode —
so the property covers the waiting rule AND the decode together.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback
    from repro._hypothesis_fallback import (  # noqa: F401
        given, settings, strategies as st,
    )

from repro.api.cluster import CodedCluster
from repro.core.grouping import (
    GroupedHGCCode,
    GroupTolerance,
    compatible_K_grouped,
    plan_grouped,
)
from repro.core import jncss
from repro.core.schemes import SCHEME_NAMES, make_scheme
from repro.core.topology import Topology

# 2×3 workers with K = W = 6: every (s_e, s_w) pair is construction-
# compatible, so the registry sweep hits all schemes at one K.
TOPO = Topology.uniform(2, 3)
K = 6
PARAMS = CodedCluster.hetero(2, 3).params
DIM = 5


@pytest.fixture(scope="module")
def schemes():
    return [
        make_scheme(n, TOPO, K, s_e=1, s_w=1, params=PARAMS, seed=0)
        for n in SCHEME_NAMES
    ]


def _boosted_sample(seed: int, slow_edges, slow_workers):
    """Random runtimes with targeted stragglers boosted 100×: the
    waiting rule then drops exactly the boosted nodes (when tolerated),
    exercising patterns uniform sampling would rarely produce."""
    rng = np.random.default_rng(seed)
    wt, eu, wd = PARAMS.sample_iteration(rng, 2.0)
    wt = wt.copy()
    eu = eu.copy()
    wd = wd.copy()
    for w in slow_workers:
        wt[w % TOPO.total_workers] *= 100.0
        wd[w % TOPO.total_workers] *= 100.0
    for e in slow_edges:
        eu[e % TOPO.n] *= 100.0
    return wt, eu, wd


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    slow_edges=st.lists(st.integers(0, 1), max_size=1),
    slow_workers=st.lists(st.integers(0, 5), max_size=2, unique=True),
)
def test_every_exact_scheme_decodes_exact_sum(
    schemes, seed, slow_edges, slow_workers
):
    sample = _boosted_sample(seed, slow_edges, slow_workers)
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(K, DIM))
    true = g.sum(axis=0)
    for sch in schemes:
        out = sch.iteration(sample)
        got = sch.gradient(g, out)
        if sch.exact:
            np.testing.assert_allclose(
                got, true, rtol=1e-7, atol=1e-7,
                err_msg=f"{sch.name} at seed={seed}",
            )
        else:  # greedy: partial by design, still well-shaped
            assert got.shape == true.shape and np.all(np.isfinite(got))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_grouped_code_decodes_any_tolerated_pattern(data):
    """Grouped codes: exact decode for EVERY straggler pattern within
    (s_e, s_w^i) — drawn directly, not via runtimes, to cover corner
    patterns (all drops at one edge, the max-tolerance edge, etc.)."""
    topo = Topology.uniform(2, 4)
    gtol = GroupTolerance(1, (0, 2))
    code = GroupedHGCCode.build(
        topo, gtol, K=compatible_K_grouped(topo, gtol, at_least=8)
    )
    seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(code.K, DIM))
    n_dead_edges = data.draw(st.integers(0, gtol.s_e), label="edges")
    dead_edges = list(rng.choice(
        topo.n, size=n_dead_edges, replace=False
    ))
    worker_stragglers = []
    for i in range(topo.n):
        s_i = data.draw(
            st.integers(0, gtol.s_w_of(i)), label=f"s_w_{i}"
        )
        worker_stragglers.append(tuple(rng.choice(
            topo.m[i], size=s_i, replace=False
        )))
    out = code.simulate_iteration(g, dead_edges, worker_stragglers)
    np.testing.assert_allclose(
        out, g.sum(axis=0), rtol=1e-7, atol=1e-7,
        err_msg=f"edges={dead_edges} workers={worker_stragglers}",
    )


def test_grouped_plan_never_slower_than_jncss():
    """The grouped search space contains every uniform vector, so its
    model-expected time is a lower envelope of JNCSS's."""
    for params, K_ in ((PARAMS, 6), (CodedCluster.hetero(2, 4).params, 8)):
        rj = jncss.solve(params, K_)
        rg = plan_grouped(params, K_)
        assert rg.T_tol <= rj.T_tol + 1e-9


def test_grouped_loads_follow_per_edge_tolerance():
    topo = Topology.uniform(2, 4)
    gtol = GroupTolerance(1, (0, 2))
    code = GroupedHGCCode.build(
        topo, gtol, K=compatible_K_grouped(topo, gtol, at_least=8)
    )
    W = topo.total_workers
    for i, D_i in enumerate(code.loads):
        assert D_i == code.K * (gtol.s_e + 1) * (gtol.s_w_of(i) + 1) // W
    assert code.load == max(code.loads)
    assert list(code.load_array) == [2.0] * 4 + [6.0] * 4


def test_grouped_tolerance_validation():
    topo = Topology.uniform(2, 4)
    with pytest.raises(ValueError, match="entries"):
        GroupTolerance(1, (0,)).validate(topo)
    with pytest.raises(ValueError, match="outside"):
        GroupTolerance(1, (0, 4)).validate(topo)
    with pytest.raises(ValueError, match="outside"):
        GroupTolerance(2, (0, 0)).validate(topo)
    # uniform guarantee is the per-edge minimum
    assert GroupTolerance(1, (0, 2)).s_w == 0
