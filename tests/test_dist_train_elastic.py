"""Elastic-state persistence + the mesh-aware ``--dist`` train driver.

Four contracts:

  1. ``StragglerDetector`` state survives a JSON round trip exactly
     (checkpoint ``extra`` is JSON — replans after restore must see the
     same EWMA buffers),
  2. ``CheckpointStore`` round-trips array-valued ``extra`` entries
     (error-feedback residuals) bit-for-bit,
  3. the ``--dist coded`` driver reproduces the single-host ``--dist
     off`` loss trajectory on a real 8-host-device mesh with ZERO
     recompiles across a forced straggler drop + JNCSS replan,
  4. killing a ``--dist coded_int8`` run mid-schedule and resuming from
     the checkpoint reproduces the uninterrupted run bit-for-bit
     (detector EWMA, deployed (tolerance, K) and EF residuals all come
     back from checkpoint ``extra``).

The driver tests run in subprocesses so the forced 8-device flag never
conflicts with this session's jax.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.runtime_model import ClusterParams
from repro.core.topology import Topology
from repro.dist.elastic import StragglerDetector


# ----------------------------------------------------------------------
# 1. detector EWMA round trip
# ----------------------------------------------------------------------
def test_detector_state_roundtrip_exact():
    topo = Topology.uniform(2, 4)
    params = ClusterParams.homogeneous(
        topo, c=10.0, gamma=0.05, tau_w=50.0, p_w=0.2, tau_e=100.0,
        p_e=0.1,
    )
    det = StragglerDetector(params, alpha=0.3)
    rng = np.random.default_rng(0)
    for _ in range(5):
        det.observe(rng.exponential(100.0, size=topo.total_workers))
    # JSON round trip — what checkpoint meta.json actually does
    blob = json.loads(json.dumps(det.state_dict()))
    det2 = StragglerDetector(params, alpha=0.9)
    det2.load_state_dict(blob)
    assert det2.alpha == det.alpha
    assert det2.n_obs == det.n_obs
    np.testing.assert_array_equal(det2.ewma, det.ewma)
    np.testing.assert_array_equal(
        det2.updated_params(2.0).c, det.updated_params(2.0).c
    )


def test_detector_state_roundtrip_before_first_observation():
    topo = Topology.uniform(2, 2)
    params = ClusterParams.homogeneous(
        topo, c=1.0, gamma=0.1, tau_w=1.0, p_w=0.1, tau_e=1.0, p_e=0.1,
    )
    det = StragglerDetector(params)
    det2 = StragglerDetector(params)
    det2.load_state_dict(json.loads(json.dumps(det.state_dict())))
    assert det2.ewma is None and det2.n_obs == 0


# ----------------------------------------------------------------------
# 2. checkpoint store: array-valued extra
# ----------------------------------------------------------------------
def test_checkpoint_store_array_extra_roundtrip(tmp_path):
    from repro.checkpoint.store import CheckpointStore

    store = CheckpointStore(str(tmp_path / "ck"), keep=2)
    state = {"params": {"w": np.arange(6, dtype=np.float32)}}
    residual = {
        "w": np.random.default_rng(0).normal(size=(2, 3)).astype(np.float32),
        "layers": [np.ones((2, 4), np.float32), np.zeros((2,), np.float32)],
    }
    extra = {
        "streams": [{"seed": 1, "step": 7}],
        "detector": {"alpha": 0.3, "n_obs": 4, "ewma": [1.5, 2.5]},
        "ef_residual": residual,
    }
    store.save(3, state, extra=extra)
    step, got_state, got_extra = store.restore()
    assert step == 3
    # JSON-able keys ride meta.json unchanged
    assert got_extra["streams"] == extra["streams"]
    assert got_extra["detector"] == extra["detector"]
    # array-valued keys ride extra.npz bit-for-bit
    np.testing.assert_array_equal(got_extra["ef_residual"]["w"], residual["w"])
    for a, b in zip(got_extra["ef_residual"]["layers"], residual["layers"]):
        np.testing.assert_array_equal(a, b)


def test_init_pod_residuals_shapes():
    import jax.numpy as jnp

    from repro.dist.compression import init_pod_residuals

    tree = {"a": jnp.ones((3, 5)), "b": [jnp.zeros(7)]}
    res = init_pod_residuals(tree, 4)
    assert res["a"].shape == (4, 3, 5) and res["a"].dtype == jnp.float32
    assert res["b"][0].shape == (4, 7)
    assert float(jnp.sum(jnp.abs(res["a"]))) == 0.0


# ----------------------------------------------------------------------
# driver subprocess harness
# ----------------------------------------------------------------------
def _run_train(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"
    return r.stdout


def _losses(path):
    with open(path) as f:
        return json.load(f)


# ----------------------------------------------------------------------
# 3. coded == off (+ zero recompiles across forced drop + replan)
# ----------------------------------------------------------------------
def test_dist_coded_matches_off_zero_recompile(tmp_path):
    # sgd: adamw's second-moment rescale chaotically amplifies fp32
    # reduction-order differences between the full-batch and the
    # hierarchical-psum gradient (both are exact decodes)
    base = [
        "--arch", "llama3-8b", "--smoke", "--scheme", "hgc_jncss",
        "--cluster", "hetero", "--n-edges", "2", "--n-workers", "4",
        "--steps", "4", "--seq-len", "16", "--log-every", "4",
        "--optimizer", "sgd", "--lr", "0.05", "--seed", "0",
        "--replan-every", "3",
        "--force-drop-edge", "1", "--force-drop-step", "2",
    ]
    off_json = str(tmp_path / "off.json")
    coded_json = str(tmp_path / "coded.json")
    _run_train(base + ["--metrics-out", off_json])
    out = _run_train(
        base + ["--dist", "coded", "--metrics-out", coded_json,
                "--expect-zero-recompile"]
    )
    assert "JNCSS chose (s_e=1" in out  # real edge tolerance planned
    off, coded = _losses(off_json), _losses(coded_json)
    # the very first loss is a pure reduction-order comparison of the
    # same decode — tight; later steps accumulate fp32 update drift
    assert abs(off["losses"][0] - coded["losses"][0]) < 1e-5
    np.testing.assert_allclose(
        off["losses"], coded["losses"], rtol=0, atol=5e-4
    )
    assert coded["jit_cache_entries"] == 1  # drop + replan: no recompile


def test_dist_int8_tracks_off(tmp_path):
    base = [
        "--arch", "llama3-8b", "--smoke", "--scheme", "hgc",
        "--n-edges", "2", "--n-workers", "4",
        "--steps", "4", "--seq-len", "16", "--log-every", "4",
        "--optimizer", "sgd", "--lr", "0.05", "--seed", "0",
    ]
    off_json = str(tmp_path / "off.json")
    q_json = str(tmp_path / "int8.json")
    _run_train(base + ["--metrics-out", off_json])
    _run_train(base + ["--dist", "coded_int8", "--metrics-out", q_json,
                       "--expect-zero-recompile"])
    off, q = _losses(off_json), _losses(q_json)
    # modulo int8 quantization (error feedback keeps the bias bounded)
    np.testing.assert_allclose(off["losses"], q["losses"], rtol=0, atol=5e-3)
    assert q["jit_cache_entries"] == 1


# ----------------------------------------------------------------------
# 4. kill/resume of --dist coded_int8 is bit-for-bit
# ----------------------------------------------------------------------
def test_int8_kill_resume_bit_for_bit(tmp_path):
    """6-step run vs (3 steps → kill → resume): identical losses.

    replan-every=2 forces a JNCSS replan (and with seed 5 a tolerance
    CHANGE) before the kill point, so the restored run must rebuild the
    replanned code + detector EWMA + EF residuals from checkpoint
    ``extra`` — priors alone would diverge.
    """
    base = [
        "--arch", "llama3-8b", "--smoke", "--scheme", "hgc_jncss",
        "--n-edges", "2", "--n-workers", "4", "--seq-len", "16",
        "--log-every", "2", "--dist", "coded_int8",
        "--replan-every", "2", "--seed", "5",
        "--steps", "6", "--checkpoint-every", "3",
    ]
    full_json = str(tmp_path / "full.json")
    p1_json = str(tmp_path / "p1.json")
    p2_json = str(tmp_path / "p2.json")
    _run_train(base + ["--checkpoint-dir", str(tmp_path / "ck_full"),
                       "--metrics-out", full_json])
    kill_dir = str(tmp_path / "ck_kill")
    out = _run_train(base + ["--checkpoint-dir", kill_dir,
                             "--stop-after", "3",
                             "--metrics-out", p1_json])
    assert "simulated kill" in out
    out = _run_train(base + ["--checkpoint-dir", kill_dir, "--resume",
                             "--metrics-out", p2_json])
    assert "resumed from step 3" in out
    full = _losses(full_json)["losses"]
    p1 = _losses(p1_json)["losses"]
    p2 = _losses(p2_json)["losses"]
    assert full[:3] == p1   # bit-for-bit, not allclose
    assert full[3:] == p2
    # the checkpoint really carried the elastic state
    extra_npz = os.path.join(
        kill_dir, "step_0000000003", "extra.npz"
    )
    assert os.path.exists(extra_npz)
    meta = json.load(open(os.path.join(
        kill_dir, "step_0000000003", "meta.json"
    )))
    assert meta["extra"]["detector"]["n_obs"] == 3
    assert {"s_e", "s_w", "K"} <= set(meta["extra"]["code"])
