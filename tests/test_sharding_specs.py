"""Sharding-rule properties: every param/batch/cache spec the dryrun
builds must satisfy pjit's divisibility requirement on BOTH production
meshes for EVERY assigned architecture — without compiling anything.

This is the fast guard for the multi-pod dry-run deliverable: a rule
regression shows up here in seconds instead of in a 30-minute sweep.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import SHAPES, TrainConfig
    from repro.configs.registry import ARCH_IDS, get_config, shape_applicable
    from repro.dist import sharding as sh
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh

    def axis_prod(mesh, entry):
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    def check(spec_tree, abs_tree, mesh, what):
        leaves_s = jax.tree.leaves(
            spec_tree, is_leaf=lambda x: isinstance(x, P))
        leaves_a = jax.tree.leaves(abs_tree)
        assert len(leaves_s) == len(leaves_a), (what, "structure")
        for s, a in zip(leaves_s, leaves_a):
            for dim, entry in zip(a.shape, tuple(s)):
                if entry is None:
                    continue
                n = axis_prod(mesh, entry)
                assert dim % n == 0, (what, a.shape, s)

    modes_checked = 0
    for multi_pod in (False, True):
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            tcfg = TrainConfig(microbatch=32)
            params_abs, opt_abs = steps_lib.abstract_state(cfg, tcfg)
            for mode in ("2d", "dp_only"):
                pspecs = sh.fit_pspecs(
                    sh.params_pspecs(params_abs, cfg, mesh, mode=mode),
                    params_abs, mesh)
                check(pspecs, params_abs, mesh, (arch, mode, "params"))
                ospecs = sh.fit_pspecs(
                    sh.opt_state_pspecs(opt_abs, pspecs), opt_abs, mesh)
                check(ospecs, opt_abs, mesh, (arch, mode, "opt"))
                modes_checked += 1
            for sname, shape in SHAPES.items():
                ok, _ = shape_applicable(cfg, shape)
                if not ok:
                    continue
                if shape.kind == "decode":
                    cache_abs = steps_lib.abstract_cache(cfg, shape)
                    cspecs = sh.fit_pspecs(
                        sh.cache_pspecs(cache_abs, mesh), cache_abs, mesh)
                    check(cspecs, cache_abs, mesh, (arch, sname, "cache"))
                else:
                    batch_abs = steps_lib.input_specs(cfg, shape)
                    bsp = {k: v for k, v in
                           sh.batch_pspecs(cfg, mesh).items()
                           if k in batch_abs}
                    bsp = sh.fit_pspecs(bsp, batch_abs, mesh)
                    check(bsp, batch_abs, mesh, (arch, sname, "batch"))
    print("SPECS_OK", modes_checked)
    """
)


def test_all_specs_divide_on_both_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPECS_OK 40" in r.stdout  # 10 archs × 2 meshes × 2 modes


_SEQ_ANCHOR_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.dist import sharding as sh
    from repro.dist.mesh import make_test_mesh
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tf
    from repro.optim import make_optimizer

    mesh = make_test_mesh(2, 2, 2)

    # 1) the anchor layout itself: seq=True pins the SEQ dim (axis 1)
    # to "model" and leaves the feature dim whole — the GSPMD
    # counterpart of the dist path's ShardCtx seq_shard regime
    # NOTE: fresh lambdas — the anchor context is read at TRACE time,
    # so a shared jit cache entry would leak the first layout in
    x = jnp.zeros((8, 16, 8), jnp.float32)
    with mesh, sh.activation_sharding(mesh, seq=True):
        y = jax.jit(lambda a: sh.anchor_activations(a))(x)
    assert y.sharding.spec == P(("pod", "data"), "model"), y.sharding
    with mesh, sh.activation_sharding(mesh):  # default: feature on model
        y = jax.jit(lambda a: sh.anchor_activations(a))(x)
    assert y.sharding.spec == P(("pod", "data"), None, "model"), y.sharding

    # 2) end to end: a pjit train step compiles AND steps under the
    # seq-parallel anchors (no shard_map — pure GSPMD)
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              dtype="float32")
    tcfg = TrainConfig(optimizer="sgd", lr=0.05, total_steps=10,
                       warmup_steps=1, grad_clip=0.0)
    opt = make_optimizer("sgd")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    B, S = 8, 16
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32),
        "weights": jnp.ones((B, S), jnp.float32),
        "denom": jnp.float32(B * S),
    }
    sh.validate_seq_shard(cfg, int(mesh.shape["model"]), S)
    step = steps_lib.make_train_step(cfg, tcfg, optimizer=opt)
    # ground truth: the unsharded single-jit step (no mesh, no anchors)
    _, _, m_ref = jax.jit(lambda *a: step(*a))(
        params, opt_state, batch, jnp.asarray(0))
    loss_ref = float(m_ref["loss"])
    with mesh, sh.activation_sharding(mesh, seq=True):
        pspecs = sh.fit_pspecs(
            sh.params_pspecs(params, cfg, mesh), params, mesh)
        p_sh = sh.to_shardings(pspecs, mesh)
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(
            opt_state,
            sh.to_shardings(sh.fit_pspecs(
                sh.opt_state_pspecs(opt_state, pspecs),
                opt_state, mesh), mesh))
        b_sh = {k: NamedSharding(
                    mesh, P(("pod", "data"), *([None] * (v.ndim - 1)))
                    if v.ndim else P())
                for k, v in batch.items()}
        batch_s = {k: jax.device_put(v, b_sh[k])
                   for k, v in batch.items()}
        new_p, _, m = jax.jit(lambda *a: step(*a))(
            params_s, opt_s, batch_s, jnp.asarray(0))
        loss_seq = float(m["loss"])
    # deliberately compared against the UNSHARDED reference; the
    # feature-anchored (seq=False) x FSDP combination has its own
    # xfail case below (test_pjit_feature_anchor_fsdp_divergence).
    # The seq layout is exact against ground truth even with FSDP on.
    assert abs(loss_seq - loss_ref) < 2e-5, (loss_seq, loss_ref)
    print("SEQ_ANCHOR_OK", f"{loss_seq:.5f}")
    """
)


def test_pjit_seq_shard_anchors():
    """--seq-shard is not dist-only: the pjit path compiles and steps
    with the activation anchors in the sequence-parallel layout (seq
    dim pinned to "model"), matching the feature-sharded layout's loss.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, "-c", _SEQ_ANCHOR_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    assert "SEQ_ANCHOR_OK" in r.stdout


_FEATURE_FSDP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.dist import sharding as sh
    from repro.dist.mesh import make_test_mesh
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tf
    from repro.optim import make_optimizer

    mesh = make_test_mesh(2, 2, 2)
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              dtype="float32")
    tcfg = TrainConfig(optimizer="sgd", lr=0.05, total_steps=10,
                       warmup_steps=1, grad_clip=0.0)
    opt = make_optimizer("sgd")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    B, S = 8, 16
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32),
        "weights": jnp.ones((B, S), jnp.float32),
        "denom": jnp.float32(B * S),
    }
    step = steps_lib.make_train_step(cfg, tcfg, optimizer=opt)
    _, _, m_ref = jax.jit(lambda *a: step(*a))(
        params, opt_state, batch, jnp.asarray(0))
    loss_ref = float(m_ref["loss"])
    # the suspect combination: legacy feature-anchored activations
    # (seq=False, feature dim on "model") with FSDP-sharded params
    with mesh, sh.activation_sharding(mesh):
        pspecs = sh.fit_pspecs(
            sh.params_pspecs(params, cfg, mesh, fsdp=True), params, mesh)
        params_s = jax.device_put(params, sh.to_shardings(pspecs, mesh))
        opt_s = jax.device_put(
            opt_state,
            sh.to_shardings(sh.fit_pspecs(
                sh.opt_state_pspecs(opt_state, pspecs),
                opt_state, mesh), mesh))
        b_sh = {k: NamedSharding(
                    mesh, P(("pod", "data"), *([None] * (v.ndim - 1)))
                    if v.ndim else P())
                for k, v in batch.items()}
        batch_s = {k: jax.device_put(v, b_sh[k])
                   for k, v in batch.items()}
        _, _, m = jax.jit(lambda *a: step(*a))(
            params_s, opt_s, batch_s, jnp.asarray(0))
        loss_feat = float(m["loss"])
    assert abs(loss_feat - loss_ref) < 2e-5, (loss_feat, loss_ref)
    print("FEATURE_FSDP_OK", f"{loss_feat:.5f}")
    """
)


@pytest.mark.xfail(
    strict=False,
    reason="feature-anchored (seq=False) activations x FSDP-sharded "
    "params diverge numerically on jax 0.4.37 / XLA:CPU (fsdp=False "
    "and the seq=True layout are both exact against the unsharded "
    "reference); the only production consumer of this combination, "
    "the dryrun, is AOT-only and never executes it",
)
def test_pjit_feature_anchor_fsdp_divergence():
    """Executable record of the known divergence: the legacy feature
    layout under FSDP should match the unsharded loss, and on current
    jax/XLA:CPU it does not.  strict=False so a toolchain that fixes
    the miscompile turns this green without blocking CI."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, "-c", _FEATURE_FSDP_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    assert "FEATURE_FSDP_OK" in r.stdout
