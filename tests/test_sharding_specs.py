"""Sharding-rule properties: every param/batch/cache spec the dryrun
builds must satisfy pjit's divisibility requirement on BOTH production
meshes for EVERY assigned architecture — without compiling anything.

This is the fast guard for the multi-pod dry-run deliverable: a rule
regression shows up here in seconds instead of in a 30-minute sweep.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import SHAPES, TrainConfig
    from repro.configs.registry import ARCH_IDS, get_config, shape_applicable
    from repro.dist import sharding as sh
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh

    def axis_prod(mesh, entry):
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    def check(spec_tree, abs_tree, mesh, what):
        leaves_s = jax.tree.leaves(
            spec_tree, is_leaf=lambda x: isinstance(x, P))
        leaves_a = jax.tree.leaves(abs_tree)
        assert len(leaves_s) == len(leaves_a), (what, "structure")
        for s, a in zip(leaves_s, leaves_a):
            for dim, entry in zip(a.shape, tuple(s)):
                if entry is None:
                    continue
                n = axis_prod(mesh, entry)
                assert dim % n == 0, (what, a.shape, s)

    modes_checked = 0
    for multi_pod in (False, True):
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            tcfg = TrainConfig(microbatch=32)
            params_abs, opt_abs = steps_lib.abstract_state(cfg, tcfg)
            for mode in ("2d", "dp_only"):
                pspecs = sh.fit_pspecs(
                    sh.params_pspecs(params_abs, cfg, mesh, mode=mode),
                    params_abs, mesh)
                check(pspecs, params_abs, mesh, (arch, mode, "params"))
                ospecs = sh.fit_pspecs(
                    sh.opt_state_pspecs(opt_abs, pspecs), opt_abs, mesh)
                check(ospecs, opt_abs, mesh, (arch, mode, "opt"))
                modes_checked += 1
            for sname, shape in SHAPES.items():
                ok, _ = shape_applicable(cfg, shape)
                if not ok:
                    continue
                if shape.kind == "decode":
                    cache_abs = steps_lib.abstract_cache(cfg, shape)
                    cspecs = sh.fit_pspecs(
                        sh.cache_pspecs(cache_abs, mesh), cache_abs, mesh)
                    check(cspecs, cache_abs, mesh, (arch, sname, "cache"))
                else:
                    batch_abs = steps_lib.input_specs(cfg, shape)
                    bsp = {k: v for k, v in
                           sh.batch_pspecs(cfg, mesh).items()
                           if k in batch_abs}
                    bsp = sh.fit_pspecs(bsp, batch_abs, mesh)
                    check(bsp, batch_abs, mesh, (arch, sname, "batch"))
    print("SPECS_OK", modes_checked)
    """
)


def test_all_specs_divide_on_both_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPECS_OK 40" in r.stdout  # 10 archs × 2 meshes × 2 modes
