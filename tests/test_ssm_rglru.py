"""Mamba-2 SSD and RG-LRU: chunked/scan forms vs naive recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: fixed-example fallback
    from repro._hypothesis_fallback import (
        given, settings, strategies as st,
    )

from repro.configs.base import ModelConfig
from repro.models import rglru as R
from repro.models import ssm as S


def _ssd_inputs(seed, B, Sq, nh, hd, N):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xbar = jax.random.normal(ks[0], (B, Sq, nh, hd)) * 0.5
    logdA = -jax.nn.softplus(jax.random.normal(ks[1], (B, Sq, nh)))
    Bc = jax.random.normal(ks[2], (B, Sq, N)) * 0.5
    Cc = jax.random.normal(ks[3], (B, Sq, N)) * 0.5
    return xbar, logdA, Bc, Cc


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 50),
    B=st.integers(1, 2),
    chunks=st.sampled_from([(8, 2), (16, 4), (16, 8)]),
)
def test_ssd_chunked_equals_recurrence(seed, B, chunks):
    Sq, chunk = chunks
    xbar, logdA, Bc, Cc = _ssd_inputs(seed, B, Sq, nh=2, hd=4, N=4)
    y_chunk, h_chunk = S.ssd_chunked(xbar, logdA, Bc, Cc, chunk=chunk)
    y_ref, h_ref = S.ssd_reference(xbar, logdA, Bc, Cc)
    np.testing.assert_allclose(y_chunk, y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h_chunk, h_ref, rtol=1e-4, atol=1e-5)


def test_ssd_chunked_with_initial_state():
    xbar, logdA, Bc, Cc = _ssd_inputs(7, 1, 16, 2, 4, 4)
    h0 = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 4, 4))
    y_c, h_c = S.ssd_chunked(xbar, logdA, Bc, Cc, chunk=4, h0=h0)
    y_r, h_r = S.ssd_reference(xbar, logdA, Bc, Cc, h0=h0)
    np.testing.assert_allclose(y_c, y_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h_c, h_r, rtol=1e-4, atol=1e-5)


def _ssm_cfg():
    return ModelConfig(
        name="t", family="ssm", n_layers=1, d_model=32, n_heads=0,
        n_kv_heads=0, head_dim=1, d_ff=0, vocab=8,
        block_pattern=("ssm",), d_state=8, expand=2, ssm_head_dim=8,
        ssm_chunk=4,
    )


def test_ssm_decode_chain_matches_forward():
    """Feeding tokens one-by-one through ssm_decode_step reproduces the
    full-sequence ssm_forward output at every position."""
    cfg = _ssm_cfg()
    rng = jax.random.PRNGKey(0)
    p = S.init_ssm(rng, cfg.d_model, cfg.expand, cfg.d_state, cfg.d_conv,
                   cfg.ssm_head_dim, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    full = S.ssm_forward(p, x, cfg)
    cache = S.ssm_init_cache(cfg, 2)
    outs = []
    for t in range(8):
        o, cache = S.ssm_decode_step(p, x[:, t : t + 1], cache, cfg)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(full, step, rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_step_chain():
    cfg = ModelConfig(
        name="t", family="hybrid", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=1, d_ff=32, vocab=8,
        block_pattern=("recurrent",), lru_width=16,
    )
    p = R.init_rglru_block(
        jax.random.PRNGKey(0), cfg.d_model, cfg.lru_width, cfg.d_conv,
        jnp.float32,
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model)) * 0.5
    full = R.rglru_block_forward(p, x, cfg)
    cache = R.rglru_init_cache(cfg, 2)
    outs = []
    for t in range(10):
        o, cache = R.rglru_block_step(p, x[:, t : t + 1], cache, cfg)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(full, step, rtol=2e-4, atol=2e-4)


def test_rglru_decay_stability():
    """|a_t| < 1 everywhere ⇒ bounded hidden states on long sequences."""
    p = R.init_rglru_block(jax.random.PRNGKey(0), 8, 8, 4, jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (1, 512, 8))
    h, _ = R.rglru_scan(p, y)
    assert jnp.all(jnp.isfinite(h))
    assert float(jnp.max(jnp.abs(h))) < 100.0


def test_ssd_gradients_finite():
    xbar, logdA, Bc, Cc = _ssd_inputs(3, 1, 16, 2, 4, 4)

    def loss(xb):
        y, _ = S.ssd_chunked(xb, logdA, Bc, Cc, chunk=4)
        return jnp.sum(y**2)

    g = jax.grad(loss)(xbar)
    assert jnp.all(jnp.isfinite(g))
