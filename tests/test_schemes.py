"""Scheme protocol: exactness, waiting rules, comm loads (paper §V-A)."""
import numpy as np
import pytest

from repro.core import tradeoff
from repro.core.runtime_model import paper_cluster
from repro.core.schemes import SCHEME_NAMES, make_scheme
from repro.core.topology import Tolerance, Topology


@pytest.fixture(scope="module")
def setting():
    params = paper_cluster("mnist")
    return params, params.topo, 40


def _all_schemes(params, topo, K):
    return [
        make_scheme(n, topo, K, s_e=1, s_w=1, params=params, seed=0)
        for n in SCHEME_NAMES
    ]


def test_exactness_flags(setting):
    params, topo, K = setting
    rng = np.random.default_rng(0)
    g = rng.normal(size=(K, 9))
    true = g.sum(axis=0)
    for sch in _all_schemes(params, topo, K):
        for t in range(20):
            sample = params.sample_iteration(rng, sch.load)
            out = sch.iteration(sample)
            got = sch.gradient(g, out)
            if sch.exact:
                np.testing.assert_allclose(
                    got, true, rtol=1e-8, atol=1e-8,
                    err_msg=f"{sch.name} iteration {t}",
                )
            else:
                assert got.shape == true.shape


def test_loads_match_theory(setting):
    params, topo, K = setting
    tol = Tolerance(1, 1)
    loads = {
        s.name: s.load for s in _all_schemes(params, topo, K)
    }
    W = topo.total_workers
    assert loads["uncoded"] == K / W
    assert loads["greedy"] == K / W
    # CGC-W ≡ HGC(0, s_w);  CGC-E ≡ HGC(s_e, 0)
    assert loads["cgc_w"] == K * 2 / W
    assert loads["cgc_e"] == K * 2 / W
    assert loads["hgc"] == float(
        tradeoff.min_load_fraction(topo, tol) * K
    )
    # flat code with equal tolerance s = s_e·m + (n−s_e)·s_w = 13
    assert loads["standard_gc"] == K * 14 / W
    # HGC load strictly below conventional equal-tolerance load (Cor. 1)
    assert loads["hgc"] < loads["standard_gc"]


def test_waiting_rules(setting):
    params, topo, K = setting
    rng = np.random.default_rng(1)
    sch = make_scheme("hgc", topo, K, s_e=1, s_w=1)
    sample = params.sample_iteration(rng, sch.load)
    out = sch.iteration(sample)
    assert len(out.fast_edges) == topo.n - 1
    for i in out.fast_edges:
        assert len(out.fast_workers[i]) == topo.m[i] - 1
    unc = make_scheme("uncoded", topo, K)
    out_u = unc.iteration(sample)
    assert len(out_u.fast_edges) == topo.n
    # uncoded waits for the global max ⇒ never faster than HGC's wait
    assert out_u.time >= out.time


def test_master_comm_loads_ordering(setting):
    """Fig. 7: StandardGC ≫ Uncoded/CGC-W ≥ CGC-E/HGC/Greedy."""
    params, topo, K = setting
    msgs = {
        s.name: s.master_messages for s in _all_schemes(params, topo, K)
    }
    assert msgs["standard_gc"] > msgs["uncoded"]
    assert msgs["uncoded"] == topo.n
    assert msgs["cgc_w"] == topo.n
    assert msgs["cgc_e"] == topo.n - 1
    assert msgs["hgc"] == topo.n - 1
    assert msgs["hgc_jncss"] <= topo.n


def test_greedy_biased_noniid(setting):
    """Greedy drops parts ⇒ non-IID parts make its aggregate biased."""
    params, topo, K = setting
    sch = make_scheme("greedy", topo, K, s_e=1, s_w=1)
    rng = np.random.default_rng(2)
    # non-IID: each part's gradient points in a distinct direction
    g = np.eye(K)
    errs = []
    for _ in range(50):
        sample = params.sample_iteration(rng, sch.load)
        out = sch.iteration(sample)
        errs.append(np.max(np.abs(sch.gradient(g, out) - g.sum(0))))
    assert max(errs) > 0.5  # materially wrong on some iterations


def test_hgc_jncss_picks_optimum(setting):
    params, topo, K = setting
    sch = make_scheme("hgc_jncss", topo, K, params=params)
    assert hasattr(sch, "jncss_result")
    from repro.core import jncss

    res = jncss.solve(params, K)
    assert (sch.s_e, sch.s_w) == (res.s_e, res.s_w)


def test_mean_iteration_time_ordering(setting):
    """Relative runtime ordering of the paper (MNIST, Fig. 8 regime)."""
    params, topo, K = setting
    rng = np.random.default_rng(3)
    means = {}
    schemes = _all_schemes(params, topo, K)
    for sch in schemes:
        ts = []
        for _ in range(300):
            sample = params.sample_iteration(rng, sch.load)
            ts.append(sch.iteration(sample).time)
        means[sch.name] = np.mean(ts)
    # headline claims of the paper, in expectation:
    assert means["hgc"] < means["uncoded"]       # HGC beats Uncoded
    assert means["hgc"] < means["cgc_w"]         # and conventional coded
    assert means["hgc_jncss"] <= means["hgc"] * 1.02  # JNCSS at least as good
