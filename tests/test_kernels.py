"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: fixed-example fallback
    from repro._hypothesis_fallback import (
        given, settings, strategies as st,
    )

from repro.core.hgc import HGCCode
from repro.core.topology import Tolerance, Topology
from repro.kernels import ops, ref
from repro.kernels.coded_combine import (
    coded_combine,
    coded_combine_f8,
    coded_combine_q,
    coded_combine_q4,
)


@settings(max_examples=25, deadline=None)
@given(
    R=st.integers(1, 12),
    K=st.sampled_from([2, 5, 8, 16, 40]),
    F=st.sampled_from([1, 7, 128, 513, 1000, 2048]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 1000),
)
def test_coded_combine_matches_ref(R, K, F, dtype, seed):
    rng = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(rng)
    coeff = jax.random.normal(k1, (R, K), jnp.float32)
    grads = jax.random.normal(k2, (K, F), jnp.float32).astype(dtype)
    out = coded_combine(coeff, grads, interpret=True)
    want = ref.coded_combine_ref(coeff, grads)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@settings(max_examples=15, deadline=None)
@given(
    R=st.integers(1, 8),
    K=st.sampled_from([2, 8, 16]),
    nF=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_coded_combine_q_matches_ref(R, K, nF, seed):
    block = 128
    F = nF * block
    rng = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(rng, 3)
    coeff = jax.random.normal(k1, (R, K), jnp.float32)
    grads_q = jax.random.randint(k2, (K, F), -127, 128, jnp.int8)
    scales = jax.random.uniform(k3, (K, F // block), jnp.float32,
                                0.01, 1.0)
    out = coded_combine_q(coeff, grads_q, scales, block=block,
                          interpret=True)
    want = ref.coded_combine_q_ref(coeff, grads_q, scales, block)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    R=st.integers(1, 8),
    K=st.sampled_from([2, 8, 16]),
    nF=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_coded_combine_q4_matches_ref(R, K, nF, seed):
    block = 128
    F = nF * block
    rng = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(rng, 3)
    coeff = jax.random.normal(k1, (R, K), jnp.float32)
    grads_q = jax.random.randint(k2, (K, F // 2), -128, 128, jnp.int8)
    scales = jax.random.uniform(k3, (K, F // block), jnp.float32,
                                0.01, 1.0)
    out = coded_combine_q4(coeff, grads_q, scales, block=block,
                           interpret=True)
    want = ref.coded_combine_q4_ref(coeff, grads_q, scales, block)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    R=st.integers(1, 8),
    K=st.sampled_from([2, 8, 16]),
    nF=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_coded_combine_f8_matches_ref(R, K, nF, seed):
    block = 128
    F = nF * block
    rng = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(rng, 3)
    coeff = jax.random.normal(k1, (R, K), jnp.float32)
    grads_q = jax.random.normal(k2, (K, F), jnp.float32).astype(
        jnp.float8_e4m3fn)
    scales = jax.random.uniform(k3, (K, F // block), jnp.float32,
                                0.01, 1.0)
    out = coded_combine_f8(coeff, grads_q, scales, block=block,
                           interpret=True)
    want = ref.coded_combine_f8_ref(coeff, grads_q, scales, block)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_combine_compressed_dispatch_matches_variants():
    """ops.combine_compressed routes each codec to its fused kernel."""
    from repro.dist import compression as comp

    rng = np.random.default_rng(7)
    K, F, block = 4, 512, 128
    coeff = jnp.asarray(rng.normal(size=(1, K)), jnp.float32)
    g = rng.normal(size=(K, F)).astype(np.float32)
    for mode in comp.COMPRESSION_MODES:
        qs, ss = [], []
        for k in range(K):
            q, s, _ = comp.quantize(g[k], block=block, mode=mode)
            qs.append(q)
            ss.append(s)
        gq, sc = jnp.stack(qs), jnp.stack(ss)
        out = ops.combine_compressed(mode, coeff, gq, sc, block=block,
                                     use_pallas=True)
        want = ops.combine_compressed(mode, coeff, gq, sc, block=block,
                                      use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # and each codec's fused path stays within quantization error
        exact = coeff @ jnp.asarray(g)
        err = np.max(np.abs(np.asarray(out) - np.asarray(exact)))
        bound = {"int8": 0.05, "int4": 0.6, "fp8": 0.3}[mode]
        assert err < bound, (mode, err)
    with pytest.raises(ValueError):
        ops.combine_compressed("int2", coeff, jnp.zeros((K, F), jnp.int8),
                               jnp.ones((K, F // block)), block=block)


def test_kernel_end_to_end_hgc_decode():
    """Kernel-based encode + decode reproduces the exact full gradient."""
    topo = Topology.uniform(3, 3)
    code = HGCCode.build(topo, Tolerance(1, 1), K=9, seed=0)
    rng = np.random.default_rng(0)
    g_parts = jnp.asarray(rng.normal(size=(9, 777)), jnp.float32)
    msgs = ops.encode_messages(code, g_parts)
    assert msgs.shape == (9, 777)
    fast_e = [0, 2]
    fast_w = [[0, 2], [], [1, 2]]
    out = ops.decode_gradient(code, msgs, fast_e, fast_w)
    np.testing.assert_allclose(
        out, np.asarray(g_parts.sum(0)), rtol=1e-5, atol=1e-5
    )


def test_flatten_roundtrip():
    tree = {
        "a": jnp.ones((3, 4), jnp.float32),
        "b": {"c": jnp.arange(5, dtype=jnp.int32)},
    }
    vec = ops.flatten_tree(tree)
    assert vec.shape == (17,)
    back = ops.unflatten_like(vec, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_quantized_combine_accuracy_vs_f32():
    """int8 path ≈ f32 path within quantization error."""
    from repro.dist.compression import quantize_int8

    rng = np.random.default_rng(1)
    K, F = 8, 1024
    coeff = jnp.asarray(rng.normal(size=(2, K)), jnp.float32)
    grads = jnp.asarray(rng.normal(size=(K, F)), jnp.float32)
    qs = [quantize_int8(np.asarray(grads[k]), block=128) for k in range(K)]
    gq = jnp.stack([jnp.asarray(q[0]).reshape(-1) for q in qs]).astype(
        jnp.int8)
    sc = jnp.stack([jnp.asarray(q[1]) for q in qs])
    out_q = coded_combine_q(coeff, gq, sc, block=128, interpret=True)
    out_f = ref.coded_combine_ref(coeff, grads)
    err = np.max(np.abs(np.asarray(out_q) - np.asarray(out_f)))
    scale = np.max(np.abs(np.asarray(out_f)))
    assert err < 0.05 * scale + 0.05
