"""Attention machinery: chunked == dense, RoPE, windows, decode, M-RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: fixed-example fallback
    from repro._hypothesis_fallback import (
        given, settings, strategies as st,
    )

from repro.models import attention as A


def _rand(rng, *shape):
    return jax.random.normal(rng, shape, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 2),
    S=st.sampled_from([8, 16, 32]),
    Kv=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
    window=st.sampled_from([0, 4, 8]),
    seed=st.integers(0, 100),
)
def test_chunked_equals_dense(B, S, Kv, G, causal, window, seed):
    rng = jax.random.PRNGKey(seed)
    ks = jax.random.split(rng, 3)
    H, Dh = Kv * G, 16
    q = _rand(ks[0], B, S, H, Dh)
    k = _rand(ks[1], B, S, Kv, Dh)
    v = _rand(ks[2], B, S, Kv, Dh)
    pos = jnp.arange(S)
    if not causal and window:
        window = 0  # windows only make sense with causality here
    dense = A.dense_attention(
        q, k, v, pos[None], pos[None], causal=causal, window=window
    )
    chunk = A.chunked_attention(
        q, k, v, pos, pos, causal=causal, window=window, kv_chunk=8
    )
    np.testing.assert_allclose(dense, chunk, rtol=2e-5, atol=2e-5)


def test_q_chunked_equals_dense():
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 3)
    B, S, Kv, G, Dh = 1, 64, 2, 2, 8
    q = _rand(ks[0], B, S, Kv * G, Dh)
    k = _rand(ks[1], B, S, Kv, Dh)
    v = _rand(ks[2], B, S, Kv, Dh)
    pos = jnp.arange(S)
    dense = A.dense_attention(q, k, v, pos[None], pos[None], causal=True)
    qc = A.chunked_attention(
        q, k, v, pos, pos, causal=True, kv_chunk=16, q_chunk=16
    )
    np.testing.assert_allclose(dense, qc, rtol=2e-5, atol=2e-5)


def test_rope_rotation_invariance():
    """RoPE preserves norms and relative-position dot products."""
    rng = jax.random.PRNGKey(1)
    x = _rand(rng, 1, 8, 2, 16)
    pos = jnp.arange(8)[None]
    r = A.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(r, axis=-1),
        rtol=1e-5,
    )
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = _rand(jax.random.PRNGKey(2), 1, 1, 1, 16)
    k = _rand(jax.random.PRNGKey(3), 1, 1, 1, 16)
    dots = []
    for p in [0, 5, 11]:
        qr = A.apply_rope(q, jnp.array([[p]]), 10_000.0)
        kr = A.apply_rope(k, jnp.array([[p + 3]]), 10_000.0)
        dots.append(float(jnp.sum(qr * kr)))
    np.testing.assert_allclose(dots, dots[0] * np.ones(3), rtol=1e-4)


def test_mrope_sections():
    """M-RoPE with identical position streams reduces to plain RoPE."""
    rng = jax.random.PRNGKey(4)
    x = _rand(rng, 2, 8, 2, 16)
    pos = jnp.arange(8)[None].repeat(2, 0)
    pos3 = jnp.broadcast_to(pos, (3, 2, 8))
    plain = A.apply_rope(x, pos, 10_000.0)
    mro = A.apply_rope(x, pos3, 10_000.0, sections=(2, 3, 3))
    np.testing.assert_allclose(plain, mro, rtol=1e-5, atol=1e-6)
    # distinct streams ⇒ different embedding
    pos3b = pos3.at[1].add(5)
    mro2 = A.apply_rope(x, pos3b, 10_000.0, sections=(2, 3, 3))
    assert not np.allclose(mro, mro2)


def test_ring_slot_positions():
    # cache of 4 slots, length 10 ⇒ positions 6..9 at slots 2,3,0,1
    got = A.ring_slot_positions(4, jnp.asarray(10), 4)
    np.testing.assert_array_equal(got, [8, 9, 6, 7])
    # shorter than window: identity with empties negative
    got = A.ring_slot_positions(4, jnp.asarray(2), 4)
    assert got[0] == 0 and got[1] == 1 and got[2] < 0 and got[3] < 0


def test_decode_matches_dense_last_row():
    """decode_attention(q_last) == dense attention's last position."""
    rng = jax.random.PRNGKey(5)
    ks = jax.random.split(rng, 3)
    B, S, Kv, G, Dh = 2, 12, 2, 2, 8
    q = _rand(ks[0], B, S, Kv * G, Dh)
    k = _rand(ks[1], B, S, Kv, Dh)
    v = _rand(ks[2], B, S, Kv, Dh)
    pos = jnp.arange(S)
    dense = A.dense_attention(q, k, v, pos[None], pos[None], causal=True)
    dec = A.decode_attention(
        q[:, -1:], k, v, jnp.asarray(S - 1), pos
    )
    np.testing.assert_allclose(dense[:, -1:], dec, rtol=2e-5, atol=2e-5)


def test_softcap_bounds_scores():
    rng = jax.random.PRNGKey(6)
    ks = jax.random.split(rng, 3)
    q = _rand(ks[0], 1, 8, 2, 8) * 100
    k = _rand(ks[1], 1, 8, 2, 8) * 100
    v = _rand(ks[2], 1, 8, 2, 8)
    pos = jnp.arange(8)
    out_cap = A.dense_attention(
        q, k, v, pos[None], pos[None], causal=True, softcap=30.0
    )
    out_plain = A.dense_attention(
        q, k, v, pos[None], pos[None], causal=True
    )
    assert jnp.all(jnp.isfinite(out_cap))
    assert not np.allclose(out_cap, out_plain)
