"""Pipeline-parallel parity: the dist-PP train step == the single-device
step, for EVERY assigned architecture.

Construction: on the 8-device (stage=2, pod=2, data=2) test mesh the
global batch is one quarter-batch tiled 4× with λ_ij = 1/4, so the
coded decode Σ λ_ij G_ij equals the plain gradient of that quarter —
which the single-device ``make_train_step`` computes directly.  The
pipelined step additionally splits each group's quarter into
microbatches and streams them through the stage pipeline (ppermute
handoffs, ``lax.scan`` over the static schedule table), so one sgd step
matching loss AND updated params proves, per arch family:

  * the tick schedule + validity masking (off-schedule cells never leak
    into the loss or, transposed, into any gradient),
  * the stage-sharded layer-group stacks (each stage scans only its own
    contiguous block) and the ``stage_correct`` gradient decode —
    stage-sharded leaves /pp, stage-replicated leaves (embedding, head,
    rest layers, final norm) psum'd over "stage" first,
  * tied embeddings whose table grad assembles from stage 0's embed
    path + the last stage's unembed path (qwen2-vl, mamba2,
    granite-moe),
  * the stage-replicated whisper encoder (runs once on the full local
    batch; per-stage cross-attention grads complete via the stage
    psum), M-RoPE microbatch slicing on batch axis 1 (qwen2-vl),
  * MoE at microbatches=1 (router capacity and the mean-based aux are
    token-count dependent, so exact parity pins M=1 — the pipeline
    still runs pp ticks end to end),
  * composition: PP∘TP (Megatron column/row-parallel inside each
    stage), PP∘TP∘SP (seq-sharded activation handoffs — the ppermute
    carries the LOCAL seq block), PP∘int8 (per-stage EF residuals ride
    the stage-sliced gradient leaf), and PP∘TP∘SP∘int8 all at once.

A separate driver test asserts the zero-recompile invariant holds with
PP on across a forced straggler drop + JNCSS replan at 16 devices.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import TrainConfig
    from repro.configs.registry import ARCH_IDS, get_smoke_config
    from repro.dist.compression import init_pod_residuals
    from repro.dist.mesh import make_test_mesh
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tf
    from repro.optim import make_optimizer

    BQ, S = 2, 16                    # group batch: what one group sees

    # smoke depths with too few layer groups for 2 stages get deepened
    # (pp shards the SCANNED groups; G must divide by the stage count)
    DEEPEN = {"granite-8b": 4, "gemma3-27b": 14, "recurrentgemma-2b": 8}
    # MoE: capacity + mean-based aux are token-count dependent — exact
    # parity pins the microbatch count to 1 (still a real pp-tick run)
    MOE_M1 = {"granite-moe-3b-a800m", "llama4-maverick-400b-a17b"}

    def build_batches(cfg, seed, groups, bq=BQ):
        rng = np.random.default_rng(seed)
        tok = rng.integers(0, cfg.vocab, size=(bq, S)).astype(np.int32)
        tgt = rng.integers(0, cfg.vocab, size=(bq, S)).astype(np.int32)
        quarter = {
            "tokens": tok,
            "targets": tgt,
            "weights": np.ones((bq, S), np.float32),
            "denom": np.float32(bq * S),
        }
        if cfg.is_encdec:
            quarter["enc_frames"] = rng.normal(
                size=(bq, cfg.enc_len, cfg.d_model)).astype(np.float32)
        full = {
            k: (v if np.ndim(v) == 0
                else np.tile(v, (groups,) + (1,) * (np.ndim(v) - 1)))
            for k, v in quarter.items()
        }
        return ({k: jnp.asarray(v) for k, v in quarter.items()},
                {k: jnp.asarray(v) for k, v in full.items()})

    def run_case(tag, cfg, seed, stages=2, pods=2, data=2, tp=1,
                 microbatches=2, compressed=False, seq_shard=False,
                 bq=BQ):
        # fp32 compute: the acceptance criterion is fp32 parity — bf16
        # activations would drown the comparison in cast noise
        cfg = dataclasses.replace(cfg, dtype="float32")
        mesh = make_test_mesh(pods, data, tp, stages=stages)
        groups = pods * data
        tcfg = TrainConfig(
            optimizer="sgd", lr=0.05, total_steps=10, warmup_steps=1,
            grad_clip=0.0,
            grad_compression="int8" if compressed else "none",
            seq_shard_activations=seq_shard,
            pp_stages=stages, microbatches=microbatches,
        )
        opt = make_optimizer("sgd")
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)
        quarter, full = build_batches(cfg, seed, groups, bq=bq)

        ref_step = jax.jit(
            steps_lib.make_train_step(cfg, tcfg, optimizer=opt))
        ref_params, _, ref_m = ref_step(
            params, opt_state, quarter, jnp.asarray(0))

        dist_step = jax.jit(
            steps_lib._make_dist_train_step(cfg, tcfg, mesh,
                                            optimizer=opt))
        lam = jnp.full((pods, data), 1.0 / groups, jnp.float32)
        residual = (init_pod_residuals(params, pods) if compressed
                    else {})
        pp_params, _, _, pp_m = dist_step(
            params, opt_state, full, lam, residual, jnp.asarray(0))

        atol_l, atol_p = (5e-3, 5e-3) if compressed else (2e-5, 3e-5)
        dl = abs(float(ref_m["loss"]) - float(pp_m["loss"]))
        assert dl < atol_l, (tag, "loss", float(ref_m["loss"]),
                             float(pp_m["loss"]))
        flat_r = jax.tree.leaves(ref_params)
        flat_t = jax.tree.leaves(pp_params)
        assert len(flat_r) == len(flat_t)
        for a, b in zip(flat_r, flat_t):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=0, atol=atol_p, err_msg=f"{tag} param mismatch")
        print(f"[pp-parity] {tag}: OK (dloss={dl:.2e})", flush=True)

    n = 0
    for i, arch in enumerate(ARCH_IDS):
        cfg = get_smoke_config(arch)
        if arch in DEEPEN:
            cfg = dataclasses.replace(cfg, n_layers=DEEPEN[arch])
        run_case(arch, cfg, seed=1000 + i,
                 microbatches=1 if arch in MOE_M1 else 2)
        n += 1
    # ---- compositions (llama3: the canonical dense arch) -------------
    base = get_smoke_config("llama3-8b")
    # PP ∘ TP: Megatron column/row-parallel inside each stage
    run_case("llama3-8b@pp2tp2", base, seed=2001,
             pods=2, data=1, tp=2)
    # PP ∘ TP ∘ SP: the ppermute handoff carries the LOCAL seq block
    run_case("llama3-8b@pp2tp2sp", base, seed=2002,
             pods=2, data=1, tp=2, seq_shard=True)
    # PP ∘ int8: per-stage EF residuals follow the stage-sliced leaf
    run_case("llama3-8b@pp2int8", base, seed=2003, compressed=True)
    # the full stack at once: PP ∘ TP ∘ SP ∘ int8
    run_case("llama3-8b@pp2tp2sp-int8", base, seed=2004,
             pods=2, data=1, tp=2, seq_shard=True, compressed=True)
    # four microbatches per stage (schedule longer than the pipeline) —
    # needs a 4-row group batch so M=4 divides the rows
    run_case("llama3-8b@pp2m4",
             dataclasses.replace(base, n_layers=4), seed=2005,
             microbatches=4, bq=4)
    print(f"PARITY_OK {n}")
    """
)


def _run(args, timeout=1500, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.setdefault("JAX_PLATFORMS", "cpu")
    if extra_env:
        env.update(extra_env)
    r = subprocess.run(
        args, capture_output=True, text=True, env=env, timeout=timeout,
    )
    return r


def test_pp_parity_all_archs():
    r = _run([sys.executable, "-c", _SCRIPT])
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    assert "PARITY_OK 10" in r.stdout


def test_pp_zero_recompile_across_drop_and_replan(tmp_path):
    """Forced straggler drop + JNCSS replan with PP on: one executable.

    Same (2 edges × 4 workers) topology as the TP acceptance run, with
    the stage axis at 2 — 16 forced host devices.  λ stays a runtime
    operand; the pipeline adds only static shape specialization.
    """
    r = _run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "llama3-8b", "--smoke", "--scheme", "hgc_jncss",
         "--cluster", "hetero", "--n-edges", "2", "--n-workers", "4",
         "--pp", "2", "--steps", "4", "--seq-len", "16",
         "--log-every", "4", "--optimizer", "sgd", "--lr", "0.05",
         "--replan-every", "3", "--force-drop-edge", "1",
         "--force-drop-step", "2", "--dist", "coded",
         "--expect-zero-recompile"],
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=16"},
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"
    assert "jit cache entries: 1" in r.stdout
    assert "pipeline stages 2" in r.stdout


def test_validate_pp_clear_errors():
    from repro.configs.registry import get_smoke_config
    from repro.dist.sharding import validate_pp

    cfg = get_smoke_config("granite-8b")  # 3 layer groups
    with pytest.raises(ValueError, match="divisib"):
        validate_pp(cfg, 2)
    validate_pp(cfg, 3)  # 3 groups over 3 stages: fine
    cfg2 = get_smoke_config("llama3-8b")  # 2 groups
    validate_pp(cfg2, 2)
    with pytest.raises(ValueError, match="microbatches"):
        validate_pp(cfg2, 2, microbatches=3, batch_rows=4)
    validate_pp(cfg2, 2, microbatches=2, batch_rows=4)


def test_stage_layer_ranges():
    import dataclasses

    from repro.configs.registry import get_smoke_config
    from repro.dist.sharding import stage_layer_ranges

    cfg = dataclasses.replace(get_smoke_config("gemma3-27b"),
                              n_layers=14)  # period 6: G=2, rest=2
    ranges = stage_layer_ranges(cfg, 2)
    assert ranges == ((0, 6), (6, 14))  # last stage owns the remainder
    cfg2 = get_smoke_config("llama3-8b")  # 2 groups of 1 layer
    assert stage_layer_ranges(cfg2, 2) == ((0, 1), (1, 2))
    assert stage_layer_ranges(cfg2, 1) == ((0, 2),)


def test_pp_flag_rejects_bad_degree():
    r = _run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "granite-8b", "--smoke", "--steps", "1",
         "--scheme", "hgc", "--s-e", "0", "--s-w", "0",
         "--dist", "coded", "--n-edges", "2", "--n-workers", "2",
         "--pp", "2"],
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
    )
    assert r.returncode != 0
    assert "divisib" in (r.stderr + r.stdout)


def test_pp_flag_requires_dist_mode():
    r = _run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "llama3-8b", "--smoke", "--steps", "1",
         "--dist", "off", "--pp", "2"],
    )
    assert r.returncode != 0
    assert "requires a --dist mode" in (r.stderr + r.stdout)
