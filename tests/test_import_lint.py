"""The public-API boundary, enforced in tier 1: examples import only
``repro.api`` (+ configs/data); the black-box system suite touches only
the CLI mains.  CI runs the same script as a standalone step."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_import_lint_passes():
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "import_lint.py")],
        capture_output=True, text=True, timeout=60,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_import_lint_catches_a_leak(tmp_path):
    # a violating example is actually flagged (guards the linter itself)
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "import_lint", REPO / "tools" / "import_lint.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert not mod._is_allowed_example("repro.launch.steps")
    assert not mod._is_allowed_example("repro.dist.grad_sync")
    assert mod._is_allowed_example("repro.api.session")
    assert mod._is_allowed_example("repro.configs.registry")
    assert mod._is_allowed_example("numpy")
    assert mod._is_allowed_system_test("repro.launch.train", ["main"])
    assert not mod._is_allowed_system_test("repro.launch.steps",
                                           ["input_specs"])
    assert not mod._is_allowed_system_test("repro.launch.train", None)
