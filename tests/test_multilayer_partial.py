"""Corollary 2 multilayer codes + partial-result (multi-message) coding."""
import numpy as np
import pytest

from repro.core import partial as P
from repro.core.hgc import HGCCode
from repro.core.multilayer import MultiLayerCode, TreeNode, min_load_fraction
from repro.core.topology import Tolerance, Topology


def test_multilayer_bound_matches_corollary2():
    assert min_load_fraction((2, 4, 8), (1, 1, 3)) == \
        pytest.approx(2 * 2 * 4 / 64)


def test_three_level_exact_recovery_no_stragglers():
    tree = TreeNode.uniform((2, 2, 2))
    code = MultiLayerCode.build(tree, s=(1, 1, 1), K=8, seed=0)
    rng = np.random.default_rng(0)
    g = rng.normal(size=(8, 5))
    out = code.decode(g)
    np.testing.assert_allclose(out, g.sum(0), rtol=1e-8, atol=1e-8)


def test_three_level_load_meets_bound():
    tree = TreeNode.uniform((2, 2, 2))
    code = MultiLayerCode.build(tree, s=(1, 1, 1), K=8, seed=0)
    # D/K = (2·2·2)/8 = 1 ⇒ D = 8 parts per worker
    assert code.load == 8
    code0 = MultiLayerCode.build(tree, s=(0, 0, 0), K=8, seed=0)
    assert code0.load == 1  # no redundancy ⇒ 1 part per worker


def test_two_level_multilayer_equals_hgc_load():
    tree = TreeNode.uniform((3, 3))
    ml = MultiLayerCode.build(tree, s=(1, 1), K=9, seed=1)
    hgc = HGCCode.build(Topology.uniform(3, 3), Tolerance(1, 1), K=9)
    assert ml.load == hgc.load == 4
    rng = np.random.default_rng(1)
    g = rng.normal(size=(9, 3))
    np.testing.assert_allclose(ml.decode(g), g.sum(0), rtol=1e-8)


# ----------------------------- partial results -------------------------
@pytest.fixture(scope="module")
def hgc_code():
    return HGCCode.build(Topology.uniform(3, 3), Tolerance(1, 1), K=9,
                         seed=0)


def test_full_prefixes_decode_exactly(hgc_code):
    code = hgc_code
    rng = np.random.default_rng(0)
    g = rng.normal(size=(code.K, 4))
    D = code.load
    for i in range(code.topo.n):
        msgs = {
            j: P.worker_prefix_messages(code, i, j, g)
            for j in range(code.topo.m[i])
        }
        # full prefixes from the fastest f_w workers must decode G_i
        out = P.edge_decode_from_prefixes(code, i, [D, D, 0], msgs)
        assert out is not None
        want = code.B.matrix[i] @ g
        np.testing.assert_allclose(out, want, rtol=1e-7, atol=1e-8)


def test_partial_prefixes_can_decode_early(hgc_code):
    """With messages from ALL workers' partial prefixes, the edge can
    decode before any single worker finishes everything — the
    Ozfatura-style speedup the paper cites as combinable."""
    code = hgc_code
    D = code.load
    # round-robin arrival: every worker completes part 1, then part 2, …
    arrivals = [(j, t) for t in range(D) for j in range(3)]
    n_needed = P.earliest_decode_progress(code, 0, arrivals)
    assert 0 < n_needed < 2 * D  # earlier than 2 workers' full results
    # and strictly fewer messages than full-HGC's f_w·D when spread out
    assert n_needed <= 2 * D


def test_insufficient_prefixes_return_none(hgc_code):
    code = hgc_code
    rng = np.random.default_rng(1)
    g = rng.normal(size=(code.K, 2))
    msgs = {0: P.worker_prefix_messages(code, 0, 0, g)[:1]}
    out = P.edge_decode_from_prefixes(code, 0, [1, 0, 0], msgs)
    assert out is None
