"""The dist ↔ core seam: λ weights and the sharded two-stage decode.

Two contracts keep the JAX execution layer honest against the numpy
reference code construction:

  1. ``grad_sync.lam_array_from_code`` is EXACTLY
     ``HGCCode.collapsed_weights`` laid out on the (pod, data) mesh —
     for both constructions and random tolerated straggler patterns,
  2. the shard_map two-stage coded aggregation reproduces
     ``HGCCode.simulate_iteration`` on a real 8-host-device mesh.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.hgc import HGCCode
from repro.core.topology import Tolerance, Topology
from repro.dist.grad_sync import lam_array_from_code


def _random_tolerated_pattern(rng, topo, tol):
    edges = rng.permutation(topo.n)
    n_dead_e = rng.integers(0, tol.s_e + 1)
    fast_e = tuple(sorted(int(i) for i in edges[: topo.n - n_dead_e]))
    fast_w = []
    for i in range(topo.n):
        order = rng.permutation(topo.m[i])
        n_dead_w = rng.integers(0, tol.s_w + 1)
        fast_w.append(
            tuple(sorted(int(j) for j in order[: topo.m[i] - n_dead_w]))
        )
    return fast_e, fast_w


@pytest.mark.parametrize("construction", ["random", "frc"])
def test_lam_array_matches_collapsed_weights(construction):
    topo = Topology.uniform(4, 4)
    tol = Tolerance(1, 1)
    code = HGCCode.build(topo, tol, K=8, seed=3, construction=construction)
    rng = np.random.default_rng(0)
    for _ in range(25):
        fast_e, fast_w = _random_tolerated_pattern(rng, topo, tol)
        lam2d = lam_array_from_code(code, fast_e, fast_w, 4, 4)
        want = code.collapsed_weights(fast_e, fast_w)
        assert lam2d.shape == (4, 4)
        np.testing.assert_array_equal(
            lam2d.reshape(-1), want.astype(np.float32)
        )


def test_lam_array_rejects_mismatched_mesh():
    topo = Topology.uniform(2, 2)
    code = HGCCode.build(topo, Tolerance(1, 1), K=4, seed=0)
    with pytest.raises(ValueError):
        lam_array_from_code(code, (0, 1), [(0,), (1,)], 2, 4)


def test_lam_zeros_exactly_on_stragglers():
    topo = Topology.uniform(2, 4)
    tol = Tolerance(1, 1)
    code = HGCCode.build(topo, tol, K=8, seed=1)
    fast_e, fast_w = (0,), [(0, 2, 3), (0, 1, 2)]
    lam = lam_array_from_code(code, fast_e, fast_w, 2, 4)
    assert np.all(lam[1] == 0.0)  # straggling edge drops whole pod row
    assert lam[0, 1] == 0.0       # straggling worker within fast edge
    assert np.any(lam[0] != 0.0)


# ----------------------------------------------------------------------
# sharded decode == numpy reference (8 CPU host devices, subprocess so
# this session's single-device jax never conflicts with the flag)
# ----------------------------------------------------------------------
_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.hgc import HGCCode
    from repro.core.topology import Tolerance, Topology
    from repro.dist._compat import shard_map
    from repro.dist.grad_sync import coded_weighted_psum, lam_array_from_code
    from repro.dist.mesh import make_test_mesh

    mesh = make_test_mesh(2, 2, 2)
    topo = Topology.uniform(2, 2)
    tol = Tolerance(1, 1)

    def sim_selection(code, e_str, w_str):
        # mirror simulate_iteration's fast-set truncation exactly
        n, s_e, s_w = code.topo.n, code.tol.s_e, code.tol.s_w
        fast_e = [i for i in range(n) if i not in set(e_str)][: n - s_e]
        fast_w = []
        for i in range(n):
            mi = code.topo.m[i]
            fw = [j for j in range(mi) if j not in set(w_str[i])]
            fast_w.append(tuple(fw[: mi - s_w]) if i in fast_e else ())
        return tuple(fast_e), fast_w

    fn = shard_map(
        lambda m, l: coded_weighted_psum({"g": m[0, 0]}, l.reshape(()))["g"],
        mesh=mesh,
        in_specs=(P("pod", "data", None), P("pod", "data")),
        out_specs=P(),
        check_rep=False,
    )
    fn = jax.jit(fn)

    rng = np.random.default_rng(7)
    for construction in ("random", "frc"):
        code = HGCCode.build(topo, tol, K=4, seed=0,
                             construction=construction)
        g = rng.normal(size=(code.K, 96))
        msgs = np.stack([
            code.worker_encode(i, j, g) for i in range(2) for j in range(2)
        ])
        for e_str, w_str in [
            ((), [(1,), (0,)]),    # 1 worker straggler per edge
            ((0,), [(), (1,)]),    # edge 0 down + 1 worker straggler
            ((), [(), ()]),        # nobody late (sim still truncates)
        ]:
            fast_e, fast_w = sim_selection(code, e_str, w_str)
            lam = lam_array_from_code(code, fast_e, fast_w, 2, 2,
                                      dtype=np.float64)
            want = code.simulate_iteration(g, e_str, w_str)
            got = np.asarray(
                fn(jnp.asarray(msgs.reshape(2, 2, -1)), jnp.asarray(lam))
            )
            np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
            np.testing.assert_allclose(want, g.sum(0), rtol=1e-7, atol=1e-9)
    print("SEAM_OK")
    """
)


def test_sharded_decode_matches_simulate_iteration():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SEAM_OK" in r.stdout
