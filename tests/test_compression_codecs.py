"""Property tests for the blockwise codec family (int8 / int4 / fp8).

Shared contract (dist/compression.py): flat payload padded to a block
multiple, one f32 scale per block, pad positions masked out of the
scale reduction and quantized to exactly zero, EF residuals telescope.
"""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: fixed-example fallback
    from repro._hypothesis_fallback import (
        given, settings, strategies as st,
    )

from repro.dist import compression as C

MODES = C.COMPRESSION_MODES  # ("int8", "int4", "fp8")

# worst-case |x̂ − x| as a multiple of the block max-abs: half a grid
# step for the int codecs, one e4m3 mantissa ulp (2^-3) + rounding for
# fp8 (values scale to ≤ 448 where the ulp is 32 ⇒ 16/448 ≈ 0.036)
_REL_ERR = {"int8": 0.5 / 127, "int4": 0.5 / 7, "fp8": 16.5 / 448}


@settings(max_examples=40, deadline=None)
@given(
    mode=st.sampled_from(MODES),
    n=st.sampled_from([1, 2, 7, 63, 64, 65, 129, 1000]),
    block=st.sampled_from([32, 64, 256]),
    scale=st.sampled_from([1e-4, 1.0, 1e4]),
    seed=st.integers(0, 1000),
)
def test_roundtrip_error_bound(mode, n, block, scale, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    q, s, meta = C.quantize(x, block=block, mode=mode)
    back = np.asarray(C.dequantize(q, s, meta))
    assert back.shape == x.shape
    assert meta.mode == mode and meta.pad == (-n) % block
    # per-block error bound: |x̂ − x| ≤ rel · blockmax
    xpad = np.pad(x, (0, meta.pad)).reshape(-1, block)
    blockmax = np.abs(xpad).max(axis=1, keepdims=True)
    err = np.abs(np.pad(back, (0, meta.pad)).reshape(-1, block) - xpad)
    bound = _REL_ERR[mode] * blockmax + 1e-30
    assert (err <= bound * 1.01).all(), (mode, n, err.max())


@settings(max_examples=25, deadline=None)
@given(
    mode=st.sampled_from(MODES),
    n=st.sampled_from([1, 65, 130, 200]),
    seed=st.integers(0, 1000),
)
def test_pad_never_skews_scales(mode, n, seed):
    """Zero-padding is masked out of the per-block scale reduction:
    the scales of the full blocks match the unpadded prefix's, and the
    pad region quantizes to exactly zero."""
    block = 64
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32) * 100.0
    q, s, meta = C.quantize(x, block=block, mode=mode)
    full = n // block
    if full:
        _, s_prefix, _ = C.quantize(x[: full * block], block=block,
                                    mode=mode)
        np.testing.assert_array_equal(np.asarray(s)[:full],
                                      np.asarray(s_prefix))
    if meta.pad:
        back = np.asarray(C.dequantize(q, s, meta))
        # dequantizing the padded payload directly exposes the tail
        flat = np.asarray(q)
        if mode == "int4":
            flat = np.asarray(C.unpack_int4(q))
        tail = flat[flat.size - meta.pad:]
        assert np.count_nonzero(np.asarray(tail, np.float32)) == 0
        np.testing.assert_allclose(back, x, atol=np.abs(x).max())


def test_all_zero_blocks_roundtrip_exactly():
    for mode in MODES:
        q, s, meta = C.quantize(np.zeros(192, np.float32), block=64,
                                mode=mode)
        assert np.asarray(s).max() == 0.0
        back = np.asarray(C.dequantize(q, s, meta))
        np.testing.assert_array_equal(back, np.zeros(192, np.float32))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.sampled_from([2, 64, 250]))
def test_int4_pack_unpack_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    v = rng.integers(-8, 8, size=2 * n).astype(np.int32)
    packed = C.pack_int4(jnp.asarray(v))
    assert packed.shape == (n,) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(C.unpack_int4(packed)), v)


def test_int4_requires_even_block():
    with pytest.raises(ValueError):
        C.quantize_int4(np.ones(8, np.float32), block=3)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        C.quantize(np.ones(8, np.float32), mode="int2")


@settings(max_examples=15, deadline=None)
@given(
    mode=st.sampled_from(MODES),
    T=st.sampled_from([3, 8]),
    seed=st.integers(0, 1000),
)
def test_error_feedback_telescopes(mode, T, seed):
    """Σ_t sent_t + r_T = T·g + r_0 for every codec: the transmitted
    values telescope, so the time-averaged gradient is unbiased."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal(100), jnp.float32),
         "b": jnp.asarray(rng.standard_normal(13), jnp.float32)}
    res = {k: jnp.zeros_like(v) for k, v in g.items()}
    total = {k: jnp.zeros_like(v) for k, v in g.items()}
    for _ in range(T):
        qt, res = C.compress_error_feedback(g, res, block=32, mode=mode)
        sent = C.dequantize_tree(qt)
        total = {k: total[k] + sent[k] for k in total}
    for k in g:
        lhs = np.asarray(total[k] + res[k])
        rhs = T * np.asarray(g[k])
        np.testing.assert_allclose(lhs, rhs, rtol=2e-5, atol=2e-5)


def test_wire_bytes_per_value():
    assert C.wire_bytes_per_value("int4", 256) < \
        C.wire_bytes_per_value("int8", 256) == \
        C.wire_bytes_per_value("fp8", 256) < 4.0
    np.testing.assert_allclose(C.wire_bytes_per_value("int4", 64),
                               0.5 + 4.0 / 64)
