"""Fused ring-buffer decode-attention kernel vs the XLA decode path.

Three layers of parity, all in Pallas interpret mode (the same
pallas_call compiles on TPU):

  * kernel vs the pure-jnp oracle (`kernels.ref.decode_attention_ref`)
    and vs `models.attention.decode_attention` across GQA shapes, ring
    wrap-around, partial fills, sliding windows, and logit softcap,
  * `decode_step(use_pallas=True)` vs the XLA path, logits allclose,
  * greedy generation token-for-token over a ring-wrapped prompt.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: fixed-example fallback
    from repro._hypothesis_fallback import (
        given, settings, strategies as st,
    )

from repro.configs.registry import get_smoke_config
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.models import attention as attn_lib
from repro.models import transformer as tf


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 3),
    C=st.sampled_from([4, 16, 40]),
    Kv=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2, 8]),
    Dh=st.sampled_from([16, 64]),
    pos_kind=st.sampled_from(["empty", "partial", "full", "wrapped"]),
    window=st.sampled_from([0, 8]),
    softcap=st.sampled_from([0.0, 30.0]),
    seed=st.integers(0, 1000),
)
def test_kernel_matches_oracles(B, C, Kv, G, Dh, pos_kind, window,
                                softcap, seed):
    pos = {"empty": 0, "partial": max(C // 2 - 1, 0), "full": C - 1,
           "wrapped": 2 * C + 3}[pos_kind]
    H = Kv * G
    weff = window if window > 0 else C
    rng = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, 1, H, Dh), jnp.float32)
    kc = jax.random.normal(k2, (B, C, Kv, Dh), jnp.float32)
    vc = jax.random.normal(k3, (B, C, Kv, Dh), jnp.float32)
    out = decode_attention_fwd(q, kc, vc, pos, window=window,
                               softcap=softcap, interpret=True)
    k_pos = attn_lib.ring_slot_positions(C, pos + 1, weff)
    want_ref = ref.decode_attention_ref(q, kc, vc, pos, k_pos,
                                        window=window, softcap=softcap)
    want_xla = attn_lib.decode_attention(q, kc, vc, pos, k_pos,
                                         window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_xla),
                               rtol=1e-5, atol=1e-5)


def test_ops_wrapper_paths_agree():
    """ops.decode_attention use_pallas=True/False give the same answer
    with only (q, caches, q_pos, window) — the serve-loop contract."""
    rng = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    B, C, Kv, G, Dh = 2, 16, 2, 2, 32
    q = jax.random.normal(k1, (B, 1, Kv * G, Dh), jnp.float32)
    kc = jax.random.normal(k2, (B, C, Kv, Dh), jnp.float32)
    vc = jax.random.normal(k3, (B, C, Kv, Dh), jnp.float32)
    for pos, window in [(3, 0), (23, 0), (37, 8)]:
        a = ops.decode_attention(q, kc, vc, pos, window=window,
                                 use_pallas=True)
        b = ops.decode_attention(q, kc, vc, pos, window=window,
                                 use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_q_pos_is_a_runtime_operand():
    """Decode positions never retrace: one jit cache entry across
    steps — the zero-recompile contract of the serve loop."""
    B, C, Kv, G, Dh = 1, 8, 2, 2, 16
    rng = jax.random.PRNGKey(1)
    q = jax.random.normal(rng, (B, 1, Kv * G, Dh), jnp.float32)
    kc = jnp.zeros((B, C, Kv, Dh), jnp.float32)
    traces = []

    @jax.jit
    def step(q, kc, pos):
        traces.append(1)
        return decode_attention_fwd(q, kc, kc, pos, interpret=True)

    for pos in range(5):
        step(q, kc, jnp.asarray(pos, jnp.int32)).block_until_ready()
    assert len(traces) == 1


# ring-wrapped GQA config (window 16 < prompt) + MQA global-attention
PARITY_ARCHS = ["gemma3-27b", "llama3-8b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_step_logits_parity(arch):
    """decode_step(use_pallas=True) == XLA path over a ring-wrapped
    chain: every step's logits agree."""
    cfg = get_smoke_config(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    B, S, max_len = 2, 24, 20  # S > max_len ⇒ even global rings wrap
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab)
    step_p = jax.jit(
        lambda p, t, c: tf.decode_step(p, cfg, t, c, use_pallas=True))
    step_x = jax.jit(
        lambda p, t, c: tf.decode_step(p, cfg, t, c, use_pallas=False))
    cache_p = tf.init_cache(cfg, B, max_len=max_len, dtype="float32")
    cache_x = tf.init_cache(cfg, B, max_len=max_len, dtype="float32")
    for t in range(S):
        lp, cache_p = step_p(params, tokens[:, t:t + 1], cache_p)
        lx, cache_x = step_x(params, tokens[:, t:t + 1], cache_x)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                                   rtol=1e-4, atol=1e-4)


def test_greedy_generation_token_for_token():
    """Greedy decode over a ring-wrapped prompt: the Pallas and XLA
    paths emit IDENTICAL token ids (the serving acceptance bar)."""
    cfg = get_smoke_config("gemma3-27b")
    params = tf.init_params(jax.random.PRNGKey(2), cfg)
    B, prompt_len, gen_len = 1, 24, 16  # 24 > window 16 ⇒ rings wrap
    assert prompt_len > cfg.window
    max_len = prompt_len + gen_len + 1
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, prompt_len),
                                0, cfg.vocab)

    def greedy(use_pallas):
        step = jax.jit(lambda p, t, c: tf.decode_step(
            p, cfg, t, c, use_pallas=use_pallas))
        cache = tf.init_cache(cfg, B, max_len=max_len, dtype="float32")
        logits = None
        for t in range(prompt_len):
            logits, cache = step(params, prompt[:, t:t + 1], cache)
        out = []
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(gen_len):
            out.append(np.asarray(tok))
            logits, cache = step(params, tok, cache)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)

    np.testing.assert_array_equal(greedy(True), greedy(False))


def test_make_decode_fn_use_pallas_switch():
    """serving.make_decode_fn threads the switch; both paths agree."""
    from repro.api import serving

    cfg = get_smoke_config("llama3-8b")
    params = tf.init_params(jax.random.PRNGKey(4), cfg)
    cache = tf.init_cache(cfg, 1, max_len=8, dtype="float32")
    tok = jnp.zeros((1, 1), jnp.int32)
    fp = jax.jit(serving.make_decode_fn(cfg, use_pallas=True))
    fx = jax.jit(serving.make_decode_fn(cfg, use_pallas=False))
    lp, _ = fp(params, tok, cache)
    lx, _ = fx(params, tok, cache)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=1e-5, atol=1e-5)
