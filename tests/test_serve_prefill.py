"""Serving path: the bulk tf.prefill → decode-cache handoff.

Contracts:
  1. bulk prefill == exact token-by-token handoff (logits AND cache),
     incl. the local-attention ring-buffer trim when the prompt exceeds
     the window,
  2. recurrent archs fall back to the exact path automatically and
     still generate,
  3. `serve --tp 2` produces tokens identical to `--tp 1` (f32 — bf16
     rounding is shard-layout-dependent) on an 8-host-device mesh.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import serving
from repro.configs.registry import get_smoke_config
from repro.models import transformer as tf


def _f32(arch):
    return dataclasses.replace(get_smoke_config(arch), dtype="float32")


def _prefill_both(cfg, B, S, max_len):
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab)
    bulk = serving.make_prefill_fn(cfg, max_len)(params, tokens)
    exact = serving.make_prefill_fn(cfg, max_len, exact=True)(
        params, tokens)
    return params, tokens, bulk, exact


@pytest.mark.parametrize("arch,S,max_len", [
    ("llama3-8b", 12, 24),        # plain GQA + rope
    ("qwen2-vl-2b", 12, 24),      # M-RoPE positions
    ("gemma3-27b", 40, 48),       # local/global: S > window=16 → ring trim
])
def test_bulk_prefill_matches_exact_handoff(arch, S, max_len):
    cfg = _f32(arch)
    assert tf.bulk_prefill_supported(cfg)
    _, _, (bl, bc), (el, ec) = _prefill_both(cfg, 2, S, max_len)
    np.testing.assert_allclose(np.asarray(bl), np.asarray(el),
                               rtol=0, atol=2e-4)
    flat_b = jax.tree.leaves(bc)
    flat_e = jax.tree.leaves(ec)
    assert len(flat_b) == len(flat_e)
    for a, b in zip(flat_b, flat_e):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0, atol=2e-4)


def test_bulk_then_decode_continues_exactly():
    """Tokens generated after a bulk handoff == after an exact handoff."""
    cfg = _f32("gemma3-27b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0,
                                cfg.vocab)
    a = serving.generate(params, cfg, prompt, 8, max_len=40)
    b = serving.generate(params, cfg, prompt, 8, max_len=40,
                         exact_handoff=True)
    np.testing.assert_array_equal(a, b)


def test_recurrent_arch_falls_back_to_exact():
    cfg = _f32("mamba2-370m")
    assert not tf.bulk_prefill_supported(cfg)
    with pytest.raises(ValueError, match="attention-only"):
        tf.prefill_to_decode_cache(cfg, {}, 16)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                cfg.vocab)
    toks = serving.generate(params, cfg, prompt, 4, max_len=16)
    assert toks.shape == (2, 4)


def test_prompt_exceeding_global_cache_is_an_error():
    cfg = _f32("llama3-8b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                                cfg.vocab)
    with pytest.raises(ValueError, match="exceeds cache size"):
        serving.prefill_into_cache(params, cfg, tokens, max_len=8)


# ----------------------------------------------------------------------
# serve CLI: tensor-parallel token parity (ISSUE acceptance)
# ----------------------------------------------------------------------
def _run_serve(args, devices=8, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve"] + args,
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"
    return r.stdout


def test_serve_tp2_tokens_identical_to_tp1(tmp_path):
    base = ["--arch", "llama3-8b", "--smoke", "--batch", "4",
            "--prompt-len", "16", "--gen", "32", "--seed", "0", "--f32"]
    t1 = str(tmp_path / "tp1.json")
    t2 = str(tmp_path / "tp2.json")
    _run_serve(base + ["--tp", "1", "--tokens-out", t1])
    out = _run_serve(base + ["--tp", "2", "--tokens-out", t2])
    assert "tp=2" in out and "bulk-prefill" in out
    tok1 = json.load(open(t1))["tokens"]
    tok2 = json.load(open(t2))["tokens"]
    assert tok1 == tok2
    assert np.asarray(tok1).shape == (4, 32)
