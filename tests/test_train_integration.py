"""End-to-end training integration: the HGC weighted-loss form.

THE system invariant (DESIGN.md §3, integration point 1): a train step
on the coded batch (examples = workers' assigned parts, weights =
coding coefficient × λ, fixed denom) produces EXACTLY the same gradient
as a plain full-batch step — under any tolerated straggler pattern.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import get_smoke_config
from repro.core.hgc import HGCCode
from repro.core.topology import Tolerance, Topology
from repro.data.pipeline import TokenStream
from repro.launch.train import build_coded_batch, _sample_straggler_pattern
from repro.core.runtime_model import ClusterParams
from repro.models import transformer as tf
from repro.optim import make_optimizer


@pytest.fixture(scope="module")
def setup():
    import dataclasses

    # f32 compute so the coded-vs-full equality is numerically sharp
    # (bf16 only reorders accumulation; exactness is algebraic)
    cfg = dataclasses.replace(
        get_smoke_config("llama3-8b"), dtype="float32"
    )
    topo = Topology.uniform(2, 4)
    code = HGCCode.build(topo, Tolerance(1, 1), K=8, seed=0)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, topo, code, params


def _grads(cfg, params, batch):
    def loss(p):
        total, _ = tf.loss_and_metrics(p, cfg, batch)
        return total

    return jax.grad(loss)(params)


def test_coded_batch_gradient_equals_full_batch(setup):
    cfg, topo, code, params = setup
    seq = 16
    streams = [
        TokenStream(cfg.vocab, 1, seq, seed=k) for k in range(code.K)
    ]
    # snapshot each part's batch (streams are stateful)
    part_batches = [s.next_batch() for s in streams]

    class Replay:
        def __init__(self, b):
            self.b = b

        def next_batch(self):
            return self.b

    replays = [Replay(b) for b in part_batches]

    # full-batch reference: all K parts, weight 1, same denom
    full = {
        "tokens": jnp.asarray(
            np.concatenate([b["tokens"] for b in part_batches])),
        "targets": jnp.asarray(
            np.concatenate([b["targets"] for b in part_batches])),
        "weights": jnp.asarray(
            np.concatenate([b["weights"] for b in part_batches])),
        "denom": jnp.float32(code.K * 1 * seq),
    }
    g_ref = _grads(cfg, params, full)

    for pattern in [
        ((0, 1), [(0, 1, 2), (1, 2, 3)]),  # max worker stragglers
        ((1,), [(), (0, 2, 3)]),           # edge 0 down
    ]:
        fast_e, fast_w = pattern
        coded = build_coded_batch(code, replays, fast_e, fast_w, seq)
        coded = {k: jnp.asarray(v) for k, v in coded.items()}
        g_coded = _grads(cfg, params, coded)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_coded)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-4, atol=2e-5,
            )


def test_train_step_runs_and_descends(setup):
    cfg, topo, code, params = setup
    tcfg = TrainConfig(optimizer="adamw", lr=5e-3, total_steps=20,
                       warmup_steps=2, microbatch=0)
    from repro.launch import steps as steps_lib

    opt = make_optimizer("adamw")
    step = jax.jit(steps_lib.make_train_step(cfg, tcfg, optimizer=opt))
    opt_state = opt.init(params)
    stream = TokenStream(cfg.vocab, 8, 16, seed=1)
    losses = []
    p = params
    for i in range(10):
        b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        p, opt_state, m = step(p, opt_state, b, jnp.asarray(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_microbatched_step_matches_full_step(setup):
    """Gradient accumulation (scan) == single big batch, same update."""
    cfg, topo, code, params = setup
    from repro.launch import steps as steps_lib

    stream = TokenStream(cfg.vocab, 8, 16, seed=2)
    b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
    b["denom"] = jnp.float32(8 * 16)  # linear loss ⇒ microbatch sums match
    opt = make_optimizer("sgd")
    outs = {}
    for mb in (0, 2):
        tcfg = TrainConfig(optimizer="sgd", lr=1e-2, microbatch=mb,
                           grad_clip=0.0, warmup_steps=1, total_steps=10)
        step = jax.jit(
            steps_lib.make_train_step(cfg, tcfg, optimizer=opt))
        p, _, m = step(params, opt.init(params), b, jnp.asarray(5))
        outs[mb] = p
    for a, c in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[2])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32),
            rtol=5e-4, atol=5e-6,
        )


def test_optimizers_step_all_archs_param_trees(setup):
    cfg, _, _, params = setup
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    for name in ("sgd", "momentum", "adamw", "adafactor"):
        opt = make_optimizer(name)
        st = opt.init(params)
        upd, st2 = opt.update(grads, st, params, 1e-3)
        for u, p in zip(jax.tree.leaves(upd), jax.tree.leaves(params)):
            assert u.shape == p.shape
            assert bool(jnp.all(jnp.isfinite(u)))
