"""Launch-layer units: the HLO analyzer (the §Roofline profiler)
and the abstract input specs — moved out of test_system.py so the
black-box suite stays on the CLI seam only."""
import pytest


# ----------------------------------------------------------------------
# HLO analyzer units (the §Roofline profiler)
# ----------------------------------------------------------------------
_HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups={{0,1}}, to_apply=%add9
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%add9 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,8]) -> f32[8,8] {
  %arg = f32[8,8] parameter(0)
  %init = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%init, %arg)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_trip_count_multiplication():
    from repro.launch import hlo_analysis as H

    c = H.analyze(_HLO, pod_stride=10**9)
    # one 8×8×8 dot per iteration × 5 trips = 5 · 2 · 8³ flops
    assert c.flops == pytest.approx(5 * 2 * 8**3 + 5, rel=0.2)
    ar = c.coll["all-reduce"]
    assert ar["count"] == 5
    assert ar["operand_bytes"] == 5 * 8 * 8 * 4
    assert ar["link_bytes"] == 2 * 5 * 8 * 8 * 4
    # bf16eq counts the f32 all-reduce at 2 bytes
    assert ar["link_bytes_bf16eq"] == 2 * 5 * 8 * 8 * 2


def test_hlo_analyzer_collective_classification():
    from repro.launch import hlo_analysis as H

    # groups within one pod (stride < 256)
    assert not H._classify_groups(
        "all-reduce(), replica_groups={{0,1,2,3}}", 256)
    # groups spanning pods
    assert H._classify_groups(
        "all-reduce(), replica_groups={{0,256}}", 256)


# ----------------------------------------------------------------------
# sequence-parallelism validation (mirrors the validate_tp cases)
# ----------------------------------------------------------------------
def test_validate_seq_shard_divisibility():
    from repro.configs.registry import get_smoke_config
    from repro.dist.sharding import validate_seq_shard

    cfg = get_smoke_config("llama3-8b")
    with pytest.raises(ValueError, match="divisible"):
        validate_seq_shard(cfg, tp=2, seq_len=17)  # 17 % 2 != 0
    with pytest.raises(ValueError, match="requires tensor parallelism"):
        validate_seq_shard(cfg, tp=1, seq_len=16)
    validate_seq_shard(cfg, tp=2, seq_len=16)  # fine, and no warning


def test_validate_seq_shard_recurrent_fallback_warns():
    """SSD / RG-LRU scans are sequential in seq: --seq-shard is legal
    but falls back to gather-before-scan — the validator says so."""
    from repro.configs.registry import get_smoke_config
    from repro.dist.sharding import validate_seq_shard

    for arch in ("mamba2-370m", "recurrentgemma-2b"):
        with pytest.warns(UserWarning, match="gather-before-scan"):
            validate_seq_shard(get_smoke_config(arch), tp=2, seq_len=16)


def test_seq_shard_flag_overrides_config_default(monkeypatch):
    """Precedence: explicit CodedSession/CLI flag > TrainConfig-level
    ``seq_shard_activations`` default."""
    from repro.api import CodedCluster, CodedSession
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_smoke_config

    cfg = get_smoke_config("llama3-8b")
    cluster = CodedCluster.homogeneous(2, 2)

    def session(**kw):
        return CodedSession(cluster, cfg, planner="uniform",
                            total_steps=2, verbose=False, **kw)

    # no flag → the dataclass default (False) is consumed
    s = session(mode="off")
    assert s.tcfg.seq_shard_activations is False
    # config-level default flipped on → consumed when no flag is given
    monkeypatch.setattr(
        TrainConfig.__dataclass_fields__["seq_shard_activations"],
        "default", True)
    assert session(mode="off").seq_shard is True  # default applies…
    # …but an explicit flag wins over the config default
    s = session(mode="off", seq_shard=False)
    assert s.tcfg.seq_shard_activations is False
    # an EXPLICIT --seq-shard without a dist mode is a flag error
    # (a config-level default in the same spot is quietly inert)
    with pytest.raises(ValueError, match="dist mode"):
        session(mode="off", seq_shard=True)


def test_input_specs_cover_all_cells():
    """input_specs returns well-formed abstract inputs for all 40 cells."""
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCH_IDS, get_config, shape_applicable
    from repro.launch.steps import input_specs

    n = 0
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, _ = shape_applicable(cfg, s)
            if not ok:
                continue
            specs = input_specs(cfg, s)
            assert specs, (a, s.name)
            for v in specs.values():
                assert all(d > 0 for d in v.shape)
            n += 1
    assert n == 32  # 40 − 8 skipped long_500k cells
