"""Launch-layer units: the HLO analyzer (the §Roofline profiler)
and the abstract input specs — moved out of test_system.py so the
black-box suite stays on the CLI seam only."""
import pytest


# ----------------------------------------------------------------------
# HLO analyzer units (the §Roofline profiler)
# ----------------------------------------------------------------------
_HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups={{0,1}}, to_apply=%add9
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%add9 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,8]) -> f32[8,8] {
  %arg = f32[8,8] parameter(0)
  %init = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%init, %arg)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_trip_count_multiplication():
    from repro.launch import hlo_analysis as H

    c = H.analyze(_HLO, pod_stride=10**9)
    # one 8×8×8 dot per iteration × 5 trips = 5 · 2 · 8³ flops
    assert c.flops == pytest.approx(5 * 2 * 8**3 + 5, rel=0.2)
    ar = c.coll["all-reduce"]
    assert ar["count"] == 5
    assert ar["operand_bytes"] == 5 * 8 * 8 * 4
    assert ar["link_bytes"] == 2 * 5 * 8 * 8 * 4
    # bf16eq counts the f32 all-reduce at 2 bytes
    assert ar["link_bytes_bf16eq"] == 2 * 5 * 8 * 8 * 2


def test_hlo_analyzer_collective_classification():
    from repro.launch import hlo_analysis as H

    # groups within one pod (stride < 256)
    assert not H._classify_groups(
        "all-reduce(), replica_groups={{0,1,2,3}}", 256)
    # groups spanning pods
    assert H._classify_groups(
        "all-reduce(), replica_groups={{0,256}}", 256)


def test_input_specs_cover_all_cells():
    """input_specs returns well-formed abstract inputs for all 40 cells."""
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCH_IDS, get_config, shape_applicable
    from repro.launch.steps import input_specs

    n = 0
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, _ = shape_applicable(cfg, s)
            if not ok:
                continue
            specs = input_specs(cfg, s)
            assert specs, (a, s.name)
            for v in specs.values():
                assert all(d > 0 for d in v.shape)
            n += 1
    assert n == 32  # 40 − 8 skipped long_500k cells
