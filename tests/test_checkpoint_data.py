"""Checkpoint store, data pipeline, compression, elastic replan."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore, config_hash
from repro.core.runtime_model import ClusterParams, paper_cluster
from repro.core.topology import Topology
from repro.data.pipeline import (
    TokenStream,
    cifar_like,
    mnist_like,
    split_K_parts,
)
from repro.dist import compression
from repro.dist.elastic import replan, shrink_topology, StragglerDetector


# ---------------------------- checkpoints -----------------------------
def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2, cfg_hash="abc")
    state = {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
        "opt": {"m": np.zeros(3), "t": np.int32(7)},
        "nested": [np.ones(2), {"x": np.float64(3.5)}],
    }
    store.save(10, state, extra={"streams": [{"seed": 1, "step": 5}]})
    step, got, extra = store.restore()
    assert step == 10
    np.testing.assert_array_equal(got["params"]["w"], state["params"]["w"])
    np.testing.assert_array_equal(got["nested"][0], np.ones(2))
    assert extra["streams"][0]["step"] == 5


def test_checkpoint_keep_n_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, {"x": np.ones(1) * s})
    assert store.manifest()["steps"] == [3, 4]
    assert not os.path.exists(str(tmp_path) + "/step_0000000001")
    step, got, _ = store.restore()
    assert step == 4 and got["x"][0] == 4.0


def test_checkpoint_config_hash_mismatch(tmp_path):
    s1 = CheckpointStore(str(tmp_path), cfg_hash="aaa")
    s1.save(1, {"x": np.ones(1)})
    s2 = CheckpointStore(str(tmp_path), cfg_hash="bbb")
    with pytest.raises(ValueError):
        s2.restore()


def test_checkpoint_restore_specific_step(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=5)
    for s in (5, 10):
        store.save(s, {"x": np.ones(1) * s})
    step, got, _ = store.restore(step=5)
    assert step == 5 and got["x"][0] == 5.0


# ---------------------------- data pipeline ---------------------------
def test_token_stream_deterministic_resume():
    a = TokenStream(vocab=100, batch=2, seq_len=8, seed=3)
    batches = [a.next_batch() for _ in range(4)]
    b = TokenStream(vocab=100, batch=2, seq_len=8, seed=3)
    b.load_state_dict({"seed": 3, "step": 2})
    np.testing.assert_array_equal(
        b.next_batch()["tokens"], batches[2]["tokens"]
    )


def test_non_iid_levels_restrict_classes():
    x, y = mnist_like(2000, seed=0)
    for level, max_classes in ((1, 10), (2, 5), (3, 2)):
        parts = split_K_parts(x, y, K=10, non_iid_level=level, seed=1)
        assert len(parts) == 10
        worst = max(len(np.unique(py)) for _, py in parts)
        assert worst <= max_classes + 3  # refill slack for exhausted classes
        if level == 3:
            typical = np.median([len(np.unique(py)) for _, py in parts])
            assert typical <= 3


def test_parts_are_disjoint_and_cover():
    x, y = mnist_like(1000, seed=2)
    parts = split_K_parts(x, y, K=8, non_iid_level=1, seed=0)
    sizes = [len(py) for _, py in parts]
    assert all(s == sizes[0] for s in sizes)
    assert cifar_like(100)[0].shape == (100, 32, 32, 3)


# ---------------------------- compression -----------------------------
def test_int8_quantization_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)) * 3.0, jnp.float32)
    q, s, meta = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, s, meta)
    assert back.shape == x.shape
    err = np.max(np.abs(np.asarray(back - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127 * 1.01


def test_error_feedback_unbiased_over_time():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    res = jax.tree.map(lambda x: jnp.zeros_like(x), {"g": g})
    total_sent = jnp.zeros_like(g)
    T = 30
    for _ in range(T):
        q, res = compression.compress_error_feedback({"g": g}, res)
        total_sent = total_sent + compression.dequantize_tree(q)["g"]
    np.testing.assert_allclose(
        np.asarray(total_sent / T), np.asarray(g), atol=2e-2
    )


def test_quantize_tree_roundtrip_shapes():
    tree = {"a": jnp.ones((3, 5)), "b": {"c": jnp.arange(7, dtype=jnp.float32)}}
    q = compression.quantize_tree(tree)
    back = compression.dequantize_tree(q)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.shape == y.shape


# ---------------------------- elastic ---------------------------------
def test_shrink_topology_removes_nodes():
    params = paper_cluster("mnist")
    small = shrink_topology(params, dead_edges=[3],
                            dead_workers=[(0, 0), (1, 5)])
    assert small.topo.n == 3
    assert small.topo.m == (9, 9, 10)
    assert small.c.shape == (28,)


def test_replan_after_failure_still_decodes():
    params = paper_cluster("mnist")
    surv = shrink_topology(params, dead_edges=[3])
    plan = replan(surv, K=40)
    code = plan.code
    rng = np.random.default_rng(0)
    g = rng.normal(size=(code.K, 5))
    out = code.simulate_iteration(g)
    np.testing.assert_allclose(out, g.sum(0), rtol=1e-8)


def test_straggler_detector_tracks_drift():
    params = paper_cluster("mnist")
    det = StragglerDetector(params, alpha=0.5)
    base = params.expected_worker_total(1.0)
    slow = base.copy()
    slow[0] += 500.0  # worker 0 got persistently slower
    for _ in range(20):
        det.observe(slow)
    upd = det.updated_params(D_ref=1.0)
    assert upd.c[0] > params.c[0] + 400
    assert np.allclose(upd.c[1:], params.c[1:], atol=1.0)
