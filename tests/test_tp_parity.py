"""Tensor-parallel parity: the dist-TP train step == the single-device
step, for EVERY assigned architecture.

Construction: on the 8-device (pod=2, data=2, model=2) test mesh the
global batch is one quarter-batch tiled 4× with λ_ij = 1/4, so the
coded decode Σ λ_ij G_ij equals the plain gradient of that quarter —
which the single-device ``make_train_step`` computes directly.  One
sgd step then must produce the same loss and the same updated params
(fp32 reduction-order tolerance).  This exercises, per arch family:

  * column/row-parallel attention (incl. the replicated-KV GQA
    fallback where n_kv_heads doesn't divide tp),
  * vocab-parallel logits + the fused-psum cross-entropy (untied) and
    the row-parallel tied unembed,
  * head-sharded SSD (mamba2), row-parallel RG-LRU gates
    (recurrentgemma), encoder-decoder cross-attention (whisper),
    M-RoPE (qwen2-vl),
  * MoE expert parallelism + the uniform-weight aux-gradient decode
    (granite-moe, llama4) — these archs previously RAISED in
    make_dist_train_step,
  * the int8 + error-feedback cross-pod hop under TP (looser tol),
  * sequence parallelism (``seq_shard_activations``): every arch again
    with the activations seq-sharded over "model" between the TP
    collective pairs — row-parallel reduce-scatter, local-seq norms /
    residuals, column-parallel all-gather, the gather-before-scan
    fallback of the recurrent stacks, and the seq_sharded_mask
    gradient correction.

A separate driver test asserts the zero-recompile invariant holds with
TP on across a forced straggler drop + JNCSS replan.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import TrainConfig
    from repro.configs.registry import ARCH_IDS, get_smoke_config
    from repro.dist.compression import init_pod_residuals
    from repro.dist.mesh import make_test_mesh
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tf
    from repro.optim import make_optimizer

    BQ, S = 2, 16                    # group batch: what one group sees

    def build_batches(cfg, seed, groups):
        rng = np.random.default_rng(seed)
        tok = rng.integers(0, cfg.vocab, size=(BQ, S)).astype(np.int32)
        tgt = rng.integers(0, cfg.vocab, size=(BQ, S)).astype(np.int32)
        quarter = {
            "tokens": tok,
            "targets": tgt,
            "weights": np.ones((BQ, S), np.float32),
            "denom": np.float32(BQ * S),
        }
        if cfg.is_encdec:
            quarter["enc_frames"] = rng.normal(
                size=(BQ, cfg.enc_len, cfg.d_model)).astype(np.float32)
        full = {
            k: (v if np.ndim(v) == 0
                else np.tile(v, (groups,) + (1,) * (np.ndim(v) - 1)))
            for k, v in quarter.items()
        }
        return ({k: jnp.asarray(v) for k, v in quarter.items()},
                {k: jnp.asarray(v) for k, v in full.items()})

    def run_case(tag, cfg, seed, pods=2, data=2, tp=2, compressed=False,
                 seq_shard=False):
        # fp32 compute: the acceptance criterion is fp32 parity — bf16
        # activations would drown the comparison in cast noise
        cfg = dataclasses.replace(cfg, dtype="float32")
        mesh = make_test_mesh(pods, data, tp)
        groups = pods * data
        tcfg = TrainConfig(
            optimizer="sgd", lr=0.05, total_steps=10, warmup_steps=1,
            grad_clip=0.0,
            grad_compression="int8" if compressed else "none",
            seq_shard_activations=seq_shard,
        )
        opt = make_optimizer("sgd")
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)
        quarter, full = build_batches(cfg, seed, groups)

        ref_step = jax.jit(
            steps_lib.make_train_step(cfg, tcfg, optimizer=opt))
        ref_params, _, ref_m = ref_step(
            params, opt_state, quarter, jnp.asarray(0))

        dist_step = jax.jit(
            steps_lib.make_dist_train_step(cfg, tcfg, mesh, optimizer=opt))
        lam = jnp.full((pods, data), 1.0 / groups, jnp.float32)
        residual = (init_pod_residuals(params, pods) if compressed else {})
        tp_params, _, _, tp_m = dist_step(
            params, opt_state, full, lam, residual, jnp.asarray(0))

        atol_l, atol_p = (5e-3, 5e-3) if compressed else (2e-5, 3e-5)
        dl = abs(float(ref_m["loss"]) - float(tp_m["loss"]))
        assert dl < atol_l, (tag, "loss", float(ref_m["loss"]),
                             float(tp_m["loss"]))
        flat_r = jax.tree.leaves(ref_params)
        flat_t = jax.tree.leaves(tp_params)
        assert len(flat_r) == len(flat_t)
        for a, b in zip(flat_r, flat_t):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=0, atol=atol_p, err_msg=f"{tag} param mismatch")
        print(f"[tp-parity] {tag}: OK (dloss={dl:.2e})", flush=True)
        return dl

    import warnings

    n = 0
    for i, arch in enumerate(ARCH_IDS):
        run_case(arch, get_smoke_config(arch), seed=1000 + i)
        # sequence-parallel regime: same parity bar, activations
        # seq-sharded between the TP collective pairs (S=16, tp=2)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # recurrent SP fallback
            run_case(arch + "@sp", get_smoke_config(arch),
                     seed=1000 + i, seq_shard=True)
        n += 1
    # replicated-KV GQA fallback with Kv > 1: tp=4, n_kv_heads=2 — each
    # shard's Q block must slice the ONE KV head of its group
    run_case("starcoder2-3b@tp4-kvrep",
             get_smoke_config("starcoder2-3b"), seed=2001,
             pods=1, data=2, tp=4)
    # replicated experts (E % tp != 0): router must NOT re-gather
    run_case("granite-moe-E5@tp2-eprep",
             dataclasses.replace(get_smoke_config("granite-moe-3b-a800m"),
                                 n_experts=5), seed=2002,
             pods=1, data=4, tp=2)
    # compressed cross-pod hop under TP (error feedback, looser tol)
    run_case("llama3-8b-int8", get_smoke_config("llama3-8b"), seed=2003,
             compressed=True)
    # sequence parallelism composes with the int8 + EF cross-pod hop
    run_case("llama3-8b-int8@sp", get_smoke_config("llama3-8b"),
             seed=2004, compressed=True, seq_shard=True)
    print(f"PARITY_OK {n}")
    """
)


def _run(args, timeout=1500, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.setdefault("JAX_PLATFORMS", "cpu")
    if extra_env:
        env.update(extra_env)
    r = subprocess.run(
        args, capture_output=True, text=True, env=env, timeout=timeout,
    )
    return r


def test_tp_parity_all_archs():
    r = _run([sys.executable, "-c", _SCRIPT])
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    assert "PARITY_OK 10" in r.stdout


def test_tp_zero_recompile_across_drop_and_replan(tmp_path):
    """Forced straggler drop + JNCSS replan with TP on: one executable.

    Same (2 edges × 4 workers) topology as the established non-TP
    acceptance run (a shape-stable replan), with the model axis at 2 —
    16 forced host devices.
    """
    r = _run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "llama3-8b", "--smoke", "--scheme", "hgc_jncss",
         "--cluster", "hetero", "--n-edges", "2", "--n-workers", "4",
         "--tp", "2", "--steps", "4", "--seq-len", "16",
         "--log-every", "4", "--optimizer", "sgd", "--lr", "0.05",
         "--replan-every", "3", "--force-drop-edge", "1",
         "--force-drop-step", "2", "--dist", "coded",
         "--expect-zero-recompile"],
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=16"},
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"
    assert "jit cache entries: 1" in r.stdout


def test_sp_zero_recompile_across_drop_and_replan(tmp_path):
    """Sequence parallelism preserves the one-executable contract:
    forced straggler drop + JNCSS replan with --seq-shard on — SP adds
    reduce-scatter/all-gather pairs, never λ-dependent shapes."""
    r = _run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "llama3-8b", "--smoke", "--scheme", "hgc_jncss",
         "--cluster", "hetero", "--n-edges", "2", "--n-workers", "4",
         "--tp", "2", "--seq-shard", "--steps", "4", "--seq-len", "16",
         "--log-every", "4", "--optimizer", "sgd", "--lr", "0.05",
         "--replan-every", "3", "--force-drop-edge", "1",
         "--force-drop-step", "2", "--dist", "coded",
         "--expect-zero-recompile"],
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=16"},
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"
    assert "jit cache entries: 1" in r.stdout
    assert "seq-parallel activations" in r.stdout


def test_validate_tp_clear_error():
    from repro.configs.registry import get_smoke_config
    from repro.dist.sharding import validate_tp

    cfg = get_smoke_config("llama3-8b")
    with pytest.raises(ValueError, match="divisib"):
        validate_tp(cfg, 3)  # d_model=64 % 3 != 0
    validate_tp(cfg, 2)      # fine — and KV=1 rides the GQA fallback


def test_tp_flag_rejects_bad_degree():
    r = _run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "llama3-8b", "--smoke", "--steps", "1",
         "--scheme", "hgc", "--s-e", "0", "--s-w", "0",
         "--dist", "coded", "--n-edges", "2", "--n-workers", "2",
         "--tp", "3"],
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
    )
    assert r.returncode != 0
    assert "divisib" in (r.stderr + r.stdout)
