"""Property-based tests of the HGC scheme (paper §III, Algorithm 1).

System invariant under test: for ANY straggler pattern within the
(s_e, s_w) tolerance, the master decodes the EXACT full gradient.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: fixed-example fallback
    from repro._hypothesis_fallback import (
        given, settings, strategies as st,
    )

from repro.core import tradeoff
from repro.core.hgc import HGCCode
from repro.core.topology import Tolerance, Topology


def _feasible_cases():
    cases = []
    for m in [(3, 3, 3), (4, 4), (2, 4, 6), (5, 5, 5, 5), (10, 10, 10, 10)]:
        topo = Topology(m=m)
        for s_e in range(min(topo.n, 3)):
            for s_w in range(min(topo.m_min, 3)):
                tol = Tolerance(s_e, s_w)
                if tradeoff.feasible(topo, tol):
                    cases.append((topo, tol))
    return cases


CASES = _feasible_cases()
_CODE_CACHE = {}


def _code_for(idx):
    if idx not in _CODE_CACHE:
        topo, tol = CASES[idx]
        _CODE_CACHE[idx] = HGCCode.build(topo, tol, seed=7)
    return _CODE_CACHE[idx]


@settings(max_examples=60, deadline=None)
@given(
    idx=st.integers(min_value=0, max_value=len(CASES) - 1),
    data=st.data(),
)
def test_exact_recovery_any_tolerated_pattern(idx, data):
    code = _code_for(idx)
    topo, tol = code.topo, code.tol
    # draw a straggler pattern within tolerance
    edge_str = data.draw(
        st.lists(
            st.integers(0, topo.n - 1),
            max_size=tol.s_e,
            unique=True,
        )
    )
    worker_str = []
    for i in range(topo.n):
        worker_str.append(
            data.draw(
                st.lists(
                    st.integers(0, topo.m[i] - 1),
                    max_size=tol.s_w,
                    unique=True,
                )
            )
        )
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    g = rng.normal(size=(code.K, 5))
    out = code.simulate_iteration(g, edge_str, worker_str)
    np.testing.assert_allclose(out, g.sum(axis=0), rtol=1e-9, atol=1e-9)


@settings(max_examples=30, deadline=None)
@given(
    idx=st.integers(min_value=0, max_value=len(CASES) - 1),
    seed=st.integers(0, 2**31),
)
def test_collapsed_weights_equal_pipeline(idx, seed):
    """λ_ij = a_i c^i_j collapsed view ≡ the two-stage decode."""
    code = _code_for(idx)
    topo, tol = code.topo, code.tol
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(code.K, 3))
    # worst-case pattern: max stragglers everywhere
    fast_edges = list(range(tol.s_e, topo.n))
    fast_workers = [
        list(range(tol.s_w, topo.m[i])) for i in range(topo.n)
    ]
    lam = code.collapsed_weights(fast_edges, fast_workers)
    total = np.zeros(3)
    for i in range(topo.n):
        for j in range(topo.m[i]):
            total += lam[topo.flat_index(i, j)] * code.worker_encode(i, j, g)
    np.testing.assert_allclose(total, g.sum(axis=0), rtol=1e-9, atol=1e-9)


def test_load_matches_theorem1_all_cases():
    for idx in range(len(CASES)):
        code = _code_for(idx)
        frac = tradeoff.min_load_fraction(code.topo, code.tol)
        assert code.load == frac * code.K


def test_worker_only_computes_assigned_parts():
    """Encoding coefficients are zero outside the assignment supports."""
    for idx in range(len(CASES)):
        code = _code_for(idx)
        for i in range(code.topo.n):
            for j in range(code.topo.m[i]):
                coeff = code.worker_coeffs(i, j)
                assigned = set(code.assignment.worker_parts(i, j))
                for k in range(code.K):
                    if k not in assigned:
                        assert coeff[k] == 0.0


def test_beyond_tolerance_fails():
    topo = Topology.uniform(3, 3)
    code = HGCCode.build(topo, Tolerance(1, 1), K=9)
    with pytest.raises(Exception):
        code.master_decode_weights([0])  # only 1 < f_e = 2 edges


def test_frc_construction_exact_and_binary():
    topo = Topology.uniform(4, 4)
    code = HGCCode.build(topo, Tolerance(1, 1), K=8, construction="frc")
    rng = np.random.default_rng(0)
    g = rng.normal(size=(8, 4))
    out = code.simulate_iteration(g, [3], [[0], [1], [2], []])
    np.testing.assert_allclose(out, g.sum(axis=0), rtol=1e-12)
    # FRC decode weights are exactly {0, 1} — bf16-safe at scale
    w = code.master_decode_weights([0, 1, 2])
    assert set(np.unique(w)).issubset({0.0, 1.0})
