"""End-to-end orchestrated episodes (ISSUE tentpole acceptance).

The load-bearing assertions:

  * a seeded kill + slow-edge episode keeps training through
    heartbeat-driven detection and an automatic fit-from-observations
    replan with EXACTLY ONE compiled train executable,
  * replaying the metrics-recorded completion sets into a fresh
    session reproduces the loss trajectory bit-for-bit (the metrics
    are a faithful record, and the coded semantics depend only on the
    completion set),
  * the heartbeat edge cases (satellite 3): a flapping worker, a
    simultaneous edge-pod loss, and a beat arriving during an
    in-flight replan all leave the compiled-executable count at 1.
"""
import numpy as np
import pytest

from repro.api import CodedCluster, CodedSession, FixedPlanner, ReplanError
from repro.orchestrator import events as ev_mod
from repro.orchestrator import (HeartbeatConfig, InjectionSchedule,
                                MetricsSink, Orchestrator,
                                OrchestratorConfig)
from repro.orchestrator.heartbeat import Heartbeat


def _smoke_cfg():
    from repro.configs.registry import get_smoke_config

    return get_smoke_config("llama3-8b")


def _session(seed=0, n_edges=3, n_workers=3, steps=40):
    return CodedSession(
        CodedCluster.hetero(n_edges, n_workers), _smoke_cfg(),
        planner=FixedPlanner(s_e=1, s_w=1), total_steps=steps,
        mode="off", seed=seed, verbose=False)


def _orchestrate(session, inject, steps, *, heartbeat=None,
                 metrics=None, backend="thread", cooldown=2):
    orch = Orchestrator(
        session,
        OrchestratorConfig(steps=steps, backend=backend,
                           heartbeat=heartbeat,
                           replan_cooldown=cooldown),
        schedule=(InjectionSchedule.parse(inject) if inject
                  else InjectionSchedule()),
        metrics=metrics or MetricsSink())
    summary = orch.run_episode()
    return orch, summary


# ----------------------------------------------------------------------
# the acceptance episode
# ----------------------------------------------------------------------
def test_kill_and_slow_episode_zero_recompile(tmp_path):
    """Seeded worker kill + slow edge: heartbeats detect the death,
    the controller refits the cluster from observations and replans,
    training continues, and the train step never recompiles."""
    path = str(tmp_path / "orch.jsonl")
    sess = _session()
    orch, summary = _orchestrate(
        sess, "kill:w0.1@3,slow:e1@5x2:4.0", steps=12,
        metrics=MetricsSink(path))

    assert summary["jit_cache_entries"] == 1
    assert summary["counters"]["replans"] >= 1
    assert summary["counters"]["injections_applied"] == 2
    assert summary["counters"]["decode_fallbacks"] == 0
    assert summary["detect_to_replan_ms"] is not None \
        and summary["detect_to_replan_ms"] > 0
    # the killed worker was detected via heartbeats alone
    assert orch.registry.dead_workers() == [1]
    kinds = orch.log.counts()
    assert kinds.get("worker_suspect", 0) >= 1
    assert kinds.get("worker_dead", 0) == 1
    assert kinds.get("replan", 0) >= 1
    # training progressed: one loss per non-fallback round
    assert len(sess.losses) == 12
    assert np.isfinite(sess.losses).all()

    from repro.orchestrator import read_metrics

    m = read_metrics(path)
    assert len(m["iteration"]) == 12 and len(m["summary"]) == 1
    assert all(r["decode_ok"] for r in m["iteration"])
    # the dead worker is absent from every post-detection completion set
    for r in m["iteration"]:
        if r["step"] >= 4 and 0 in r["fast_e"]:
            assert 1 not in r["fast_w"][0]


def test_replay_parity_from_metrics(tmp_path):
    """Replaying the recorded completion sets into a fresh session
    reproduces the losses bit-for-bit (metrics faithfulness)."""
    path = str(tmp_path / "orch.jsonl")
    sess = _session(seed=11)
    _orchestrate(sess, "slow:e1@2x2:3.0,partition:w2.0@5x1",
                 steps=8, metrics=MetricsSink(path))

    from repro.orchestrator import read_metrics

    records = read_metrics(path)["iteration"]
    fresh = _session(seed=11)
    for r in records:
        assert r["n_counted"] > 0
        m = fresh.external_step(tuple(r["fast_e"]),
                                [tuple(w) for w in r["fast_w"]])
        assert float(m["loss"]) == r["loss"]
    assert fresh.losses == sess.losses
    assert fresh.jit_cache_entries() == 1


# ----------------------------------------------------------------------
# satellite 3 — heartbeat edge cases, all at one compiled executable
# ----------------------------------------------------------------------
def test_flapping_worker_recovers_inside_timeout():
    """Tight deadlines on the hetero cluster: slow/partitioned workers
    miss a beat, go SUSPECT, and the held-back beat recovers them
    inside the death budget (flaps, not deaths) — cache stays at 1."""
    sess = _session(seed=2)
    hb = HeartbeatConfig(interval_ms=200.0, timeout_ms=600.0,
                         backoff=1.0, suspect_after=1, dead_after=4)
    orch, summary = _orchestrate(sess, "partition:w2.0@2x1", steps=12,
                                 heartbeat=hb)
    kinds = orch.log.counts()
    assert kinds.get("worker_recovered", 0) >= 1
    assert summary["counters"]["flaps"] >= 1
    assert kinds.get("worker_dead", 0) == 0
    assert summary["jit_cache_entries"] == 1
    assert len(sess.losses) == 12


def test_simultaneous_edge_pod_loss():
    """Killing a whole pod at once: the registry derives ONE edge_down,
    the code's s_e=1 absorbs the loss (no fallback), and the fit-replan
    fires — still one executable."""
    sess = _session(seed=3)
    orch, summary = _orchestrate(sess, "kill:e2@2", steps=14)
    kinds = orch.log.counts()
    assert kinds.get("edge_down", 0) == 1
    assert orch.registry.down_edges() == [2]
    assert summary["counters"]["replans"] >= 1
    assert summary["counters"]["decode_fallbacks"] == 0
    assert summary["jit_cache_entries"] == 1
    assert len(sess.losses) == 14
    # edge 2 never decodes after death detection
    dead_at = orch.log.first(ev_mod.EDGE_DOWN).step
    for r in orch.metrics.records:
        if r.get("record") == "iteration" and r["step"] > dead_at:
            assert 2 not in r["fast_e"]


def test_heartbeat_during_inflight_replan(monkeypatch):
    """A beat delivered in the middle of session.replan lands in the
    monitor's ledger without corrupting the episode — same compiled
    executable, consistent registry."""
    sess = _session(seed=4)
    orch = Orchestrator(
        sess, OrchestratorConfig(steps=10, backend="thread"),
        schedule=InjectionSchedule.parse("kill:w0.1@2"))

    real_replan = sess.replan
    hits = []

    def replan_with_racing_beat(planner=None, cluster=None):
        # the race: a live worker's beat arrives while the replan is
        # still in flight
        orch.monitor.deliver(
            Heartbeat(flat=3, sent_ms=orch.clock_ms, runtime_ms=150.0),
            step=len(hits))
        hits.append(1)
        return real_replan(planner=planner, cluster=cluster)

    monkeypatch.setattr(sess, "replan", replan_with_racing_beat)
    summary = orch.run_episode()
    assert hits, "episode never replanned — race not exercised"
    assert summary["jit_cache_entries"] == 1
    assert orch.registry.state_of(3) == "HEALTHY"
    assert summary["counters"]["replans"] >= 1


# ----------------------------------------------------------------------
# failure handling — ReplanError is logged, never fatal
# ----------------------------------------------------------------------
def test_replan_error_logged_not_fatal(monkeypatch):
    sess = _session(seed=5)
    orch = Orchestrator(
        sess, OrchestratorConfig(steps=10, backend="thread"),
        schedule=InjectionSchedule.parse("kill:w0.1@2"))

    def failing_replan(planner=None, cluster=None):
        raise ReplanError("grouped loads under dist",
                          constraint="uniform_load",
                          topo=sess.cluster.topo)

    monkeypatch.setattr(sess, "replan", failing_replan)
    summary = orch.run_episode()
    assert summary["counters"]["replan_errors"] >= 1
    assert summary["counters"]["replans"] == 0
    failed = orch.log.of_kind(ev_mod.REPLAN_FAILED)
    assert failed and failed[0].detail["constraint"] == "uniform_load"
    assert failed[0].detail["m"] == [3, 3, 3]
    assert summary["jit_cache_entries"] == 1
    assert len(sess.losses) == 10  # the episode kept training


def test_replan_cluster_topology_mismatch_raises():
    sess = _session(seed=6, n_edges=2, n_workers=2, steps=10)
    other = CodedCluster.hetero(3, 2)
    with pytest.raises(ReplanError) as ei:
        sess.replan(cluster=other)
    assert ei.value.constraint == "topology"
    assert ei.value.topo == sess.cluster.topo


def test_external_step_validates_completion_set():
    sess = _session(seed=7, n_edges=2, n_workers=2, steps=10)
    with pytest.raises(ValueError, match="needs >= 1"):
        sess.external_step((), [(), ()])
    with pytest.raises(ValueError, match="edge 0"):
        sess.external_step((0,), [(), (0,)])


# ----------------------------------------------------------------------
# dist mode — the orchestrator over the in-mesh coded decode
# ----------------------------------------------------------------------
def test_orchestrated_dist_coded_zero_recompile(tmp_path):
    """The full service over the (pod, data) mesh: worker pool, kill
    injection, heartbeat detection, replan — with λ decoded INSIDE the
    compiled shard_map step, still exactly one executable.  Runs in a
    subprocess so the forced 8-device flag never leaks."""
    import os
    import subprocess
    import sys

    path = str(tmp_path / "orch_dist.jsonl")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.orchestrate",
         "--smoke", "--dist", "coded", "--cluster", "hetero",
         "--n-edges", "2", "--n-workers", "4", "--steps", "8",
         "--seq-len", "16", "--scheme", "hgc", "--s-e", "1",
         "--s-w", "1", "--backend", "thread",
         "--inject", "kill:w0.1@2", "--metrics-out", path,
         "--expect-zero-recompile", "--min-replans", "1"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"

    from repro.orchestrator import read_metrics

    m = read_metrics(path)
    assert m["summary"][0]["jit_cache_entries"] == 1
    assert m["summary"][0]["counters"]["replans"] >= 1
    assert all(r["decode_ok"] for r in m["iteration"])
