"""Microbatched gradient accumulation == one full-batch step.

Covers the ``lax.scan`` accumulation path of
:func:`repro.launch.steps.make_train_step` (``tcfg.microbatch > 0``):

  * the fixed-denominator (coded) path — microbatch losses SUM to the
    full-batch loss with no ``/n_micro`` (the loss is linear in the
    per-example weights over a shared normalizer), so accumulated
    gradients must equal the single-full-batch gradients exactly in
    fp32,
  * the mean path (no ``denom`` in the batch) — per-microbatch means
    averaged over ``n_micro``; with uniform weights and equal
    microbatch sizes this equals the full-batch mean,
  * the M-RoPE split path (qwen2-vl): positions ride batch axis 1 of a
    ``(3, B, S)`` array, so the scan split must reshape on axis 1 and
    move the microbatch axis to the front.

The accumulation body is deterministic — no dropout, no RNG consumed
per microbatch — so there is no RNG-split path to cover; these cases
plus the denominator choice exhaust the scan's behavior.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import get_smoke_config
from repro.launch import steps as steps_lib
from repro.models import transformer as tf
from repro.optim import make_optimizer

B, S = 4, 16


def _batch(cfg, seed, with_denom=True, mrope=False):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32),
        "weights": jnp.ones((B, S), jnp.float32),
    }
    if with_denom:
        batch["denom"] = jnp.float32(B * S)
    if mrope:
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S))
        batch["positions"] = jnp.asarray(pos)
    return batch


def _one_step(cfg, tcfg, batch, seed=0):
    opt = make_optimizer("sgd")
    params = tf.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(cfg, tcfg, optimizer=opt))
    new_params, _, m = step(params, opt_state, batch, jnp.asarray(0))
    return new_params, float(m["loss"])


def _assert_match(cfg, with_denom, mrope=False, atol=2e-6):
    cfg = dataclasses.replace(cfg, dtype="float32")
    batch = _batch(cfg, seed=7, with_denom=with_denom, mrope=mrope)
    base = TrainConfig(optimizer="sgd", lr=0.05, total_steps=10,
                       warmup_steps=1, grad_clip=0.0)
    full_p, full_l = _one_step(cfg, base, batch)
    acc_p, acc_l = _one_step(
        cfg, dataclasses.replace(base, microbatch=2), batch)
    assert abs(full_l - acc_l) < atol, (full_l, acc_l)
    for a, b in zip(jax.tree.leaves(full_p), jax.tree.leaves(acc_p)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=0, atol=atol)


def test_accum_matches_full_batch_denom_path():
    _assert_match(get_smoke_config("llama3-8b"), with_denom=True)


def test_accum_matches_full_batch_mean_path():
    # uniform weights + equal microbatch sizes: the per-microbatch
    # means averaged over n_micro equal the full-batch mean
    _assert_match(get_smoke_config("llama3-8b"), with_denom=False)


def test_accum_matches_full_batch_mrope_split():
    _assert_match(get_smoke_config("qwen2-vl-2b"), with_denom=True,
                  mrope=True)


def test_accum_loss_sums_not_averages_on_denom_path():
    """The coded contract: with a fixed denom the metric is the SUM of
    microbatch losses (already the full-batch loss), never /n_micro."""
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              dtype="float32")
    batch = _batch(cfg, seed=9, with_denom=True)
    base = TrainConfig(optimizer="sgd", lr=0.05, total_steps=10,
                       warmup_steps=1, grad_clip=0.0)
    _, full_l = _one_step(cfg, base, batch)
    _, acc_l = _one_step(
        cfg, dataclasses.replace(base, microbatch=1), batch)  # 4 micros
    assert acc_l == pytest.approx(full_l, abs=2e-6)
