"""Serving path: token-by-token decode == full-sequence forward logits.

This is the strongest end-to-end invariant for the cache machinery
(ring buffers, RG-LRU/SSD states, cross-attention caches).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models import transformer as tf

# archs whose decode path differs structurally — all tested
DECODE_ARCHS = [
    "llama3-8b",          # plain GQA + rope
    "gemma3-27b",         # local/global pattern + ring buffers
    "qwen2-vl-2b",        # M-RoPE
    "recurrentgemma-2b",  # RG-LRU + local attention
    "whisper-medium",     # enc-dec with cross-attention caches
    "mamba2-370m",        # SSD recurrent state
    "granite-moe-3b-a800m",  # MoE FFN in decode
]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_chain_matches_forward(arch):
    cfg = get_smoke_config(arch)
    rng = jax.random.PRNGKey(0)
    params = tf.init_params(rng, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    kwargs = {}
    if cfg.is_encdec:
        kwargs["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.enc_len, cfg.d_model)
        )
    full_logits, _ = tf.forward(params, cfg, tokens, **kwargs)

    cache = tf.init_cache(cfg, B, max_len=S, dtype="float32")
    if cfg.is_encdec:
        cache = tf.fill_cross_cache(params, cfg, kwargs["enc_frames"], cache)
    step = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        logits, cache = step(params, tokens[:, t : t + 1], cache)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)  # (B, S, V)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32), np.asarray(dec, np.float32),
        rtol=5e-2, atol=5e-2,
    )
    # exactness of argmax (what serving actually needs)
    agree = np.mean(
        np.argmax(full_logits, -1) == np.argmax(dec, -1)
    )
    assert agree > 0.95, f"argmax agreement {agree}"


def test_local_ring_buffer_window_equivalence():
    """With S > window, decode with ring buffer == full forward (local)."""
    cfg = get_smoke_config("gemma3-27b")
    rng = jax.random.PRNGKey(3)
    params = tf.init_params(rng, cfg)
    B, S = 1, 40  # window is 16 in the smoke config
    assert S > cfg.window
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    full_logits, _ = tf.forward(params, cfg, tokens)
    cache = tf.init_cache(cfg, B, max_len=S, dtype="float32")
    step = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        logits, cache = step(params, tokens[:, t : t + 1], cache)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1], np.float32),
        np.asarray(dec[:, -1], np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_cache_length_advances():
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cache = tf.init_cache(cfg, 1, max_len=8)
    tok = jnp.zeros((1, 1), jnp.int32)
    _, cache = tf.decode_step(params, cfg, tok, cache)
    assert int(cache["length"]) == 1
    _, cache = tf.decode_step(params, cfg, tok, cache)
    assert int(cache["length"]) == 2
