"""JNCSS (Algorithm 2), Theorem 2 optimality, Theorem 3 bound, §IV-B cases."""
import numpy as np
import pytest

from repro.core import jncss
from repro.core.runtime_model import ClusterParams, paper_cluster
from repro.core.topology import Topology


def _tiny_params(seed=0):
    rng = np.random.default_rng(seed)
    topo = Topology(m=(3, 3))
    W, n = topo.total_workers, topo.n
    return ClusterParams(
        topo=topo,
        c=rng.uniform(5, 50, W),
        gamma=rng.uniform(0.01, 0.1, W),
        tau_w=rng.uniform(20, 100, W),
        p_w=rng.uniform(0.05, 0.5, W),
        tau_e=rng.uniform(50, 500, n),
        p_e=rng.uniform(0.05, 0.2, n),
    )


def test_theorem2_matches_brute_force():
    """Algorithm 2 output equals exhaustive P1 optimum (Theorem 2)."""
    for seed in range(5):
        params = _tiny_params(seed)
        fast = jncss.solve(params, K=12, require_feasible=False)
        bf = jncss.brute_force(params, K=12)
        assert fast.T_tol == pytest.approx(bf.T_tol, rel=1e-12)
        assert (fast.s_e, fast.s_w) == (bf.s_e, bf.s_w)


def test_vectorized_equals_reference_loops():
    params = paper_cluster("mnist")
    a = jncss.solve(params, K=40, require_feasible=False)
    b = jncss.solve_reference(params, K=40)
    assert a.T_tol == pytest.approx(b.T_tol)
    assert (a.s_e, a.s_w) == (b.s_e, b.s_w)


def test_selection_consistency():
    """e/w selections reproduce T̂ when evaluated directly."""
    params = paper_cluster("cifar")
    res = jncss.solve(params, K=40)
    assert sum(res.e) == params.topo.n - res.s_e
    B = params.expected_worker_total(res.D)
    A = params.expected_edge_upload()
    worst = -np.inf
    off = 0
    for i in range(params.topo.n):
        mi = params.topo.m[i]
        if res.e[i]:
            assert sum(res.w[i]) == mi - res.s_w
            sel = [off + j for j in range(mi) if res.w[i][j]]
            worst = max(worst, A[i] + max(B[j] for j in sel))
        else:
            assert sum(res.w[i]) == 0
        off += mi
    assert worst == pytest.approx(res.T_tol, rel=1e-12)


def test_theorem3_bound_holds_empirically():
    """E|T_tol − T̂| (Monte Carlo) ≤ the Theorem 3 bound."""
    params = paper_cluster("mnist")
    res = jncss.solve(params, K=40)
    bound = jncss.theorem3_gap_bound(params, res, n_samples=2000, seed=1)
    rng = np.random.default_rng(2)
    gaps = []
    from repro.core.runtime_model import kth_min

    topo = params.topo
    for _ in range(2000):
        wt, eu, _ = params.sample_iteration(rng, res.D)
        per_edge = []
        off = 0
        for i in range(topo.n):
            mi = topo.m[i]
            per_edge.append(
                eu[i] + kth_min(wt[off : off + mi], mi - res.s_w)
            )
            off += mi
        T = kth_min(np.array(per_edge), topo.n - res.s_e)
        gaps.append(abs(T - res.T_tol))
    assert np.mean(gaps) <= bound * 1.05  # MC slack


def test_order_stat_factor():
    assert jncss.order_stat_factor(10, 1) == pytest.approx(
        np.sqrt(9 / 10), rel=1e-12
    )
    assert jncss.order_stat_factor(10, 10) == pytest.approx(
        np.sqrt(9 / 10), rel=1e-12
    )


def test_homogeneous_case1_endpoint_optimality():
    """§IV-B Case 1: corner optimum vs full-grid numeric minimum."""
    c, K, n, m, gamma, t1, t2 = 10.0, 40, 4, 10, 0.05, 50.0, 100.0
    se, sw, v = jncss.homogeneous_case1(c, K, n, m, gamma, t1, t2)
    grid = [
        jncss.case1_expected_runtime(a, b, c, K, n, m, gamma, t1, t2)
        for a in range(n)
        for b in range(m)
    ]
    # paper's claim: the corner minimum is the global minimum of eq (35)
    assert v == pytest.approx(min(grid), rel=1e-9)


def test_homogeneous_case2_endpoint_optimality():
    c, K, n, m, t1, t2, p2 = 10.0, 40, 4, 10, 50.0, 100.0, 0.1
    se, sw, v = jncss.homogeneous_case2(c, K, n, m, t1, t2, p2)
    assert sw == 0
    grid = [
        jncss.case2_expected_runtime(a, c, K, n, m, t1, t2, p2)
        for a in range(n)
    ]
    assert v == pytest.approx(min(grid), rel=1e-9)


def test_jncss_improves_over_fixed_choice():
    """On the paper's heterogeneous cluster, JNCSS ≤ any fixed (s_e,s_w)."""
    params = paper_cluster("mnist")
    res = jncss.solve(params, K=40, with_grid=True)
    finite = res.grid[np.isfinite(res.grid)]
    assert res.T_tol == pytest.approx(finite.min())
    assert res.T_tol <= res.grid[1, 1] or not np.isfinite(res.grid[1, 1])
