"""Theorem 1 / Corollary 1 / Corollary 2 — computational trade-off."""
import math
from fractions import Fraction

import pytest

from repro.core import tradeoff
from repro.core.topology import Tolerance, Topology


def test_theorem1_reduces_to_single_layer():
    # n = 1 edge ⇒ the conventional bound (s_w+1)/m  (paper eq 3).
    topo = Topology(m=(8,))
    tol = Tolerance(s_e=0, s_w=3)
    assert tradeoff.min_load_fraction(topo, tol) == Fraction(4, 8)


def test_theorem1_example1():
    # Paper Example 1: 3 edges × 3 workers, s_e = s_w = 1, K = 9 ⇒ D = 4.
    topo = Topology.uniform(3, 3)
    tol = Tolerance(1, 1)
    assert tradeoff.min_load_fraction(topo, tol) == Fraction(4, 9)
    assert tradeoff.min_load(topo, tol, K=9) == 4
    assert tradeoff.achievable_load(topo, tol, K=9) == 4


def test_achievable_matches_lower_bound():
    # eq (23): the HGC construction meets Theorem 1 with equality.
    for m in [(4, 4), (2, 4, 6), (10, 10, 10, 10)]:
        topo = Topology(m=m)
        for s_e in range(topo.n):
            for s_w in range(topo.m_min):
                tol = Tolerance(s_e, s_w)
                if not tradeoff.feasible(topo, tol):
                    continue
                K = tradeoff.compatible_K(topo, tol, at_least=8)
                D = tradeoff.achievable_load(topo, tol, K)
                assert Fraction(D, K) == tradeoff.min_load_fraction(topo, tol)


def test_corollary1_strict_gap():
    # Conventional single-layer coding strictly exceeds the optimum
    # whenever the system is truly hierarchical (paper Corollary 1).
    cases = [
        (Topology.uniform(3, 3), Tolerance(1, 1)),
        (Topology.uniform(4, 10), Tolerance(1, 1)),
        (Topology.uniform(4, 10), Tolerance(2, 3)),
        (Topology(m=(4, 6, 8)), Tolerance(1, 2)),
    ]
    for topo, tol in cases:
        conv = tradeoff.conventional_load_fraction(topo, tol)
        opt = tradeoff.min_load_fraction(topo, tol)
        assert conv > opt, (topo, tol)


def test_corollary2_multilayer():
    # L-layer: D/K ≥ Π(s_l+1)/W; 2-layer case must agree with Theorem 1.
    topo = Topology.uniform(4, 10)
    tol = Tolerance(2, 3)
    assert tradeoff.multilayer_min_load_fraction(
        [tol.s_e, tol.s_w], topo.total_workers
    ) == tradeoff.min_load_fraction(topo, tol)
    assert tradeoff.multilayer_min_load_fraction([1, 2, 3], 120) == Fraction(
        24, 120
    )


def test_feasibility_guard():
    # Very skewed topology: tolerating the big edge leaves too few workers.
    topo = Topology(m=(8, 1, 1))
    assert not tradeoff.feasible(topo, Tolerance(1, 0))
    assert tradeoff.feasible(topo, Tolerance(0, 0))


def test_compatible_K_properties():
    topo = Topology(m=(2, 3, 5))
    tol = Tolerance(1, 1)
    K = tradeoff.compatible_K(topo, tol, at_least=7)
    assert K >= 7
    D = tradeoff.achievable_load(topo, tol, K)  # must not raise
    assert D * topo.total_workers == K * (tol.s_e + 1) * (tol.s_w + 1)


def test_invalid_tolerance_raises():
    topo = Topology.uniform(3, 3)
    with pytest.raises(ValueError):
        tradeoff.min_load_fraction(topo, Tolerance(3, 0))
    with pytest.raises(ValueError):
        tradeoff.min_load_fraction(topo, Tolerance(0, 3))
