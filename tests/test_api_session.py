"""The public object model: planner strategies, CodedCluster, and the
CodedSession elastic loop.

The load-bearing test is the shrink contract (ISSUE satellite): a
session that permanently loses a pod replans on the survivors, keeps
training, and a killed-and-resumed run reproduces the uninterrupted
trajectory bit-for-bit — the checkpoint carries the shrink record, the
replanned code, the detector EWMA and the stream states.
"""
import json
import os

import numpy as np
import pytest

from repro.api import (
    CodedCluster,
    CommBudgetPlanner,
    FixedPlanner,
    GroupedPlanner,
    JNCSSPlanner,
    Planner,
    Tolerance,
    Topology,
    UniformPlanner,
    get_planner,
    planner_for_scheme,
)


def _smoke_cfg(arch="llama3-8b"):
    from repro.configs.registry import get_smoke_config

    return get_smoke_config(arch)


# ----------------------------------------------------------------------
# planner strategies
# ----------------------------------------------------------------------
def test_planner_strategies():
    cluster = CodedCluster.hetero(2, 4)
    for spec, expect_jncss in (("jncss", True), ("fixed", False),
                               ("uniform", False), ("grouped", False),
                               ("comm_budget", False)):
        planner = get_planner(spec, 1, 1)
        assert isinstance(planner, Planner)
        K = planner.initial_K(cluster.topo)
        plan = planner.plan(cluster.params, K, seed=0)
        assert plan.K >= cluster.topo.total_workers
        assert plan.code.K == plan.K
        assert (plan.jncss is not None) == expect_jncss
        assert plan.expected_iteration_ms > 0
        # stable re-plan reuses the deployed code (identity, not copy)
        again = planner.plan(cluster.params, plan.K, seed=0,
                             reuse=plan.code)
        if again.tol == plan.tol and again.K == plan.K:
            assert again.code is plan.code
    assert get_planner("uniform").tol == Tolerance(0, 0)
    with pytest.raises(ValueError, match="unknown planner"):
        get_planner("bogus")


def test_planner_for_scheme_round_trip():
    """Every CLI --scheme name maps to the planner strategy that
    reproduces it through the CodedSession seam."""
    expected = {
        "hgc_jncss": JNCSSPlanner,
        "hgc": FixedPlanner,
        "uncoded": UniformPlanner,
        "hgc_grouped": GroupedPlanner,
        "hgc_comm": CommBudgetPlanner,
    }
    for scheme, cls in expected.items():
        assert isinstance(planner_for_scheme(scheme, 1, 1), cls), scheme
    assert planner_for_scheme("uncoded").tol == Tolerance(0, 0)
    with pytest.raises(ValueError, match="unknown planner"):
        planner_for_scheme("bogus")


def test_plan_lam_array_matches_grad_sync():
    from repro.dist.grad_sync import lam_array_from_code

    cluster = CodedCluster.hetero(2, 4)
    plan = FixedPlanner(1, 1).plan(cluster.params, 8, seed=0)
    fast_e = (0,)
    fast_w = [(0, 1, 2), (1, 2, 3)]
    np.testing.assert_array_equal(
        plan.lam_array(fast_e, fast_w),
        lam_array_from_code(plan.code, fast_e, fast_w, 2, 4),
    )
    assert plan.deployed == {"s_e": 1, "s_w": 1, "K": plan.K}


# ----------------------------------------------------------------------
# CodedCluster
# ----------------------------------------------------------------------
def test_cluster_from_observations_fits_compute_term():
    topo = Topology.uniform(2, 3)
    truth = CodedCluster.homogeneous(2, 3, c=25.0).params
    rng = np.random.default_rng(0)
    D = 2.0
    obs = [truth.sample_iteration(rng, D)[0] for _ in range(400)]
    cluster = CodedCluster.from_observations(topo, obs, D)
    # fitted per-part compute ≈ the true c (sampling noise only)
    np.testing.assert_allclose(cluster.params.c, truth.c, rtol=0.25)
    assert cluster.detector.n_obs == 400


def test_cluster_shrink_records_original_indices():
    cluster = CodedCluster.homogeneous(4, 2)
    s1 = cluster.shrink(dead_edges=[1])
    assert s1.topo.m == (2, 2, 2)
    assert s1.dead_edges == (1,)
    # second shrink uses CURRENT indexing: edge 2 of the survivors
    # [0, 2, 3] is original edge 3
    s2 = s1.shrink(dead_edges=[2])
    assert s2.topo.m == (2, 2)
    assert s2.dead_edges == (1, 3)
    # the record round-trips through a checkpoint snapshot
    restored = cluster.restored(json.loads(json.dumps(s2.state_dict())))
    assert restored.topo == s2.topo
    assert restored.dead_edges == (1, 3)


def test_cluster_shrink_translates_worker_indices():
    cluster = CodedCluster.homogeneous(2, 3)
    s1 = cluster.shrink(dead_workers=[(0, 0)])
    assert s1.topo.m == (2, 3)
    assert s1.dead_workers == ((0, 0),)
    # current worker (0, 0) of the survivors is ORIGINAL (0, 1) — a
    # repeated shrink must keep killing, not re-record the same node
    s2 = s1.shrink(dead_workers=[(0, 0)])
    assert s2.topo.m == (1, 3)
    assert s2.dead_workers == ((0, 0), (0, 1))
    # composition with a prior edge death: current edge 0 is original 1
    s3 = cluster.shrink(dead_edges=[0]).shrink(dead_workers=[(0, 2)])
    assert s3.dead_workers == ((1, 2),)
    assert s3.topo.m == (2,)


# ----------------------------------------------------------------------
# CodedSession: shrink → replan → keep training → kill/resume
# ----------------------------------------------------------------------
def _make_session(ck_dir, resume=False, steps=8):
    from repro.api import CodedSession

    return CodedSession(
        CodedCluster.homogeneous(3, 2),
        _smoke_cfg(),
        planner="jncss",
        mode="off",
        seq_len=16,
        optimizer="sgd",
        lr=0.05,
        total_steps=steps,
        seed=0,
        checkpoint_dir=str(ck_dir),
        checkpoint_every=2,
        resume=resume,
        log_every=100,
        verbose=False,
    )


def test_session_shrink_replan_kill_resume_bit_for_bit(tmp_path):
    # uninterrupted twin: 4 steps, pod 1 dies, 4 more steps
    a = _make_session(tmp_path / "a")
    a.fit(4)
    a.shrink(dead_edges=[1])
    assert a.cluster.topo.m == (2, 2)
    a.fit(8)
    assert len(a.losses) == 8 and np.all(np.isfinite(a.losses))

    # killed twin: same through step 6 (checkpointed), then a NEW
    # session constructed with the ORIGINAL cluster resumes
    b1 = _make_session(tmp_path / "b")
    b1.fit(4)
    b1.shrink(dead_edges=[1])
    b1.fit(6)
    meta = json.load(open(os.path.join(
        str(tmp_path / "b"), "step_0000000006", "meta.json")))
    assert meta["extra"]["cluster"]["dead_edges"] == [1]

    b2 = _make_session(tmp_path / "b", resume=True)
    assert b2.cluster.topo.m == (2, 2)          # shrink restored
    assert b2.cluster.dead_edges == (1,)
    assert b2.code.topo == b2.cluster.topo      # code rebuilt to match
    b2.fit(8)
    # bit-for-bit, not allclose
    assert a.losses[:6] == b1.losses
    assert a.losses[6:] == b2.losses


def test_session_replan_planner_swap_zero_recompile(tmp_path):
    """Swapping the planning STRATEGY mid-run through replan() rides the
    λ seam: grouped → jncss changes the deployed code object but not the
    per-worker load, so the jit signature is untouched (one cache entry).
    A swap that DOES change the load (comm_budget here picks a larger
    tolerance) is a real batch-shape change and costs exactly one more
    compile — never a silent per-step recompile."""
    from repro.api import CodedSession

    s = CodedSession(
        CodedCluster.hetero(2, 4),
        _smoke_cfg(),
        planner="grouped",
        mode="off",
        seq_len=16,
        optimizer="sgd",
        lr=0.05,
        total_steps=8,
        seed=0,
        log_every=100,
        verbose=False,
    )
    assert isinstance(s.planner, GroupedPlanner)
    s.fit(2)
    load_before = s.code.load
    s.replan(planner="jncss")
    assert isinstance(s.planner, JNCSSPlanner)
    assert s.code.load == load_before
    s.fit(5)
    entries = s.jit_cache_entries()
    assert entries in (-1, 1), entries  # -1: counter API unavailable
    s.replan(planner="comm_budget")
    assert isinstance(s.planner, CommBudgetPlanner)
    s.fit(8)
    assert len(s.losses) == 8 and np.all(np.isfinite(s.losses))
    entries = s.jit_cache_entries()
    expected = 1 if s.code.load == load_before else 2
    assert entries in (-1, expected), (entries, s.code.load)


def _make_grouped_session(ck_dir, resume=False, steps=8):
    from repro.api import CodedSession

    return CodedSession(
        CodedCluster.hetero(2, 4),
        _smoke_cfg(),
        planner="grouped",
        mode="off",
        seq_len=16,
        optimizer="sgd",
        lr=0.05,
        total_steps=steps,
        seed=0,
        checkpoint_dir=str(ck_dir),
        checkpoint_every=2,
        resume=resume,
        log_every=100,
        verbose=False,
    )


def test_session_grouped_kill_resume_bit_for_bit(tmp_path):
    """The checkpoint descriptor of a grouped code carries s_w_vec and
    a resumed session rebuilds a GroupedHGCCode (same trajectory)."""
    from repro.api import GroupedHGCCode
    from repro.api.session import _code_desc

    a = _make_grouped_session(tmp_path / "a")
    a.fit(8)

    b1 = _make_grouped_session(tmp_path / "b")
    b1.fit(4)
    desc = _code_desc(b1.code)
    assert "s_w_vec" in desc and desc["K"] == b1.code.K
    meta = json.load(open(os.path.join(
        str(tmp_path / "b"), "step_0000000004", "meta.json")))
    assert meta["extra"]["code"] == json.loads(json.dumps(desc))

    b2 = _make_grouped_session(tmp_path / "b", resume=True)
    assert isinstance(b2.code, GroupedHGCCode)
    assert _code_desc(b2.code) == desc
    b2.fit(8)
    assert a.losses[:4] == b1.losses
    assert a.losses[4:] == b2.losses


def test_session_dist_rejects_nonuniform_grouped_load():
    """--dist modes key batch rows to workers, so a grouped code with
    per-edge loads must be uniform-valued; the session says so up front
    instead of crashing on a shape mismatch inside shard_map."""
    from repro.api import CodedSession, GroupTolerance
    from repro.core.grouping import GroupedHGCCode, compatible_K_grouped

    topo = Topology.uniform(2, 4)
    gtol = GroupTolerance(1, (0, 2))
    code = GroupedHGCCode.build(
        topo, gtol, K=compatible_K_grouped(topo, gtol, at_least=8))
    s = CodedSession(None, _smoke_cfg())  # serve-only: just the guard
    s.mode = "coded"
    with pytest.raises(ValueError, match="uniform"):
        s._require_dist_uniform_load(code)
    s.mode = "off"
    s._require_dist_uniform_load(code)  # reference loop: fine


def test_session_step_and_eval(tmp_path):
    s = _make_session(tmp_path / "c", steps=4)
    m = s.step()
    assert np.isfinite(float(m["loss"]))
    batch = s.build_batch((0, 1, 2), [(0, 1), (0, 1), (0, 1)])
    ev = s.eval_step({k: v for k, v in batch.items() if k != "denom"})
    assert np.isfinite(ev["loss"])


def test_serve_only_session_rejects_training():
    from repro.api import CodedSession

    s = CodedSession(None, _smoke_cfg())
    with pytest.raises(RuntimeError, match="serve-only"):
        s.fit(1)
    with pytest.raises(RuntimeError, match="serve-only"):
        s.step()


def test_session_shrink_in_dist_int8_carries_residual(tmp_path):
    """Losing a pod under coded_int8 rebuilds the mesh AND carries the
    surviving pod's error-feedback residual row (not zeros, not the
    checkpointed snapshot).  Runs in a subprocess: the forced 8-device
    flag must not leak into this session's jax."""
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import jax, numpy as np
        from repro.api import CodedCluster, CodedSession
        from repro.configs.registry import get_smoke_config

        s = CodedSession(
            CodedCluster.hetero(3, 2), get_smoke_config("llama3-8b"),
            planner="fixed", mode="coded_int8", seq_len=16,
            optimizer="sgd", lr=0.05, total_steps=6, seed=0,
            log_every=100, verbose=False,
        )
        s.fit(3)
        leaf0 = np.asarray(jax.tree.leaves(s.residual)[0])
        assert leaf0.shape[0] == 3
        s.shrink(dead_edges=[1])
        leaf1 = np.asarray(jax.tree.leaves(s.residual)[0])
        assert leaf1.shape[0] == 2, leaf1.shape
        # the surviving pods' live residual rows rode the mesh rebuild
        np.testing.assert_array_equal(leaf1[0], leaf0[0])
        np.testing.assert_array_equal(leaf1[1], leaf0[2])
        assert float(np.abs(leaf1).max()) > 0.0  # not re-zeroed
        s.fit(6)
        assert len(s.losses) == 6 and np.all(np.isfinite(s.losses))
        print("SHRINK_INT8_OK")
        """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"
    assert "SHRINK_INT8_OK" in r.stdout


# ----------------------------------------------------------------------
# checkpoint schema version (ISSUE satellite)
# ----------------------------------------------------------------------
def test_checkpoint_schema_version_mismatch_is_clear(tmp_path):
    from repro.checkpoint.store import SCHEMA_VERSION, CheckpointStore

    store = CheckpointStore(str(tmp_path / "ck"))
    store.save(1, {"w": np.ones(3, np.float32)})
    meta_path = os.path.join(str(tmp_path / "ck"), "step_0000000001",
                             "meta.json")
    meta = json.load(open(meta_path))
    assert meta["schema_version"] == SCHEMA_VERSION

    # stale checkpoint from a future/past layout → clear message, not a
    # cryptic pytree-structure error
    meta["schema_version"] = SCHEMA_VERSION + 1
    json.dump(meta, open(meta_path, "w"))
    with pytest.raises(ValueError, match="schema v"):
        store.restore()

    # pre-versioning checkpoint (no stamp at all) → same clear failure
    del meta["schema_version"]
    json.dump(meta, open(meta_path, "w"))
    with pytest.raises(ValueError, match="schema v1"):
        store.restore()


def test_deprecation_shims_warn_once():
    import warnings

    from repro.core.topology import Topology
    from repro.launch import steps as steps_lib
    from repro.launch import train as train_mod

    steps_lib._WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        train_mod._make_cluster("homogeneous", Topology.uniform(2, 2))
        train_mod._make_cluster("hetero", Topology.uniform(2, 2))
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1 and "repro.api" in str(deps[0].message)
