"""Failure injector — deterministic, schedulable fault injection.

The paper's premise is that edge deployments fail in structured ways:
workers die, whole edge pods drop off the network, links degrade.  The
injector turns those into first-class, *scheduled* events against the
worker pool:

  * ``kill``      — terminate a worker (or a whole edge pod) for good:
    the process/thread stops responding permanently,
  * ``slow``      — multiply the target's compute time by ``factor``
    for ``duration`` rounds (a transient straggler / thermal event),
  * ``partition`` — drop the target's messages at the master for
    ``duration`` rounds; the worker keeps computing, the control plane
    sees silence, and when the partition heals the worker REJOINS —
    the flap/recovery path of the liveness machine.

Schedules are either parsed from a compact spec string (the CLI's
``--inject``) or drawn from a seeded RNG (``InjectionSchedule.seeded``)
— both fully deterministic, so CI episodes replay exactly.

Spec grammar (comma-separated)::

    kind:target@step[xduration][:factor]

    kill:w0.1@3        kill worker (edge 0, worker 1) at step 3
    kill:e1@4          kill ALL of edge 1's workers at step 4
    slow:e1@5x3:4.0    slow edge 1 by 4x for rounds 5,6,7
    partition:w1.0@2x2 drop worker (1,0)'s messages for rounds 2,3
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.topology import Topology

KILL = "kill"
SLOW = "slow"
PARTITION = "partition"
KINDS = (KILL, SLOW, PARTITION)

_SPEC_RE = re.compile(
    r"^(?P<kind>kill|slow|partition):"
    r"(?P<target>[we]\d+(?:\.\d+)?)"
    r"@(?P<step>\d+)"
    r"(?:x(?P<duration>\d+))?"
    r"(?::(?P<factor>\d+(?:\.\d+)?))?$"
)


@dataclasses.dataclass(frozen=True)
class Injection:
    """One scheduled fault.

    ``edge``/``worker``: worker-level faults set both; edge-level faults
    set ``worker=None`` and apply to every worker of the edge.  ``kill``
    ignores ``duration`` (permanent); ``slow``/``partition`` last
    ``duration`` rounds starting at ``step``.
    """

    kind: str
    step: int
    edge: int
    worker: Optional[int] = None
    duration: int = 1
    factor: float = 4.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown injection kind {self.kind!r}")
        if self.step < 0 or self.duration < 1:
            raise ValueError("injection needs step >= 0, duration >= 1")
        if self.kind == SLOW and self.factor <= 1.0:
            raise ValueError(f"slow factor must exceed 1, got {self.factor}")

    def active(self, step: int) -> bool:
        if self.kind == KILL:
            return step >= self.step
        return self.step <= step < self.step + self.duration

    def targets(self, topo: Topology) -> Tuple[int, ...]:
        """Flat worker indices this injection hits."""
        if self.worker is not None:
            return (topo.flat_index(self.edge, self.worker),)
        return tuple(topo.flat_index(self.edge, j)
                     for j in range(topo.m[self.edge]))

    def to_json(self) -> Dict:
        d = {"kind": self.kind, "step": self.step, "edge": self.edge}
        if self.worker is not None:
            d["worker"] = self.worker
        if self.kind != KILL:
            d["duration"] = self.duration
        if self.kind == SLOW:
            d["factor"] = self.factor
        return d

    @property
    def spec(self) -> str:
        t = (f"e{self.edge}" if self.worker is None
             else f"w{self.edge}.{self.worker}")
        s = f"{self.kind}:{t}@{self.step}"
        if self.kind != KILL and self.duration != 1:
            s += f"x{self.duration}"
        if self.kind == SLOW:
            s += f":{self.factor:g}"
        return s


@dataclasses.dataclass(frozen=True)
class RoundEffects:
    """The injector's verdict for one round, consumed by the pool."""

    killed: FrozenSet[int]                 # flat ids: stop permanently
    partitioned: FrozenSet[int]            # flat ids: drop messages
    slow: Dict[int, float]                 # flat id -> compute multiplier
    started: Tuple[Injection, ...]         # injections starting this round

    def slow_factor(self, flat: int) -> float:
        return self.slow.get(flat, 1.0)


class InjectionSchedule:
    """An ordered, deterministic set of :class:`Injection`."""

    def __init__(self, injections: Sequence[Injection] = ()):
        self.injections = tuple(sorted(
            injections, key=lambda x: (x.step, x.kind, x.edge,
                                       -1 if x.worker is None else x.worker)
        ))

    @classmethod
    def parse(cls, spec: str) -> "InjectionSchedule":
        """Parse the CLI grammar (see module docstring)."""
        out: List[Injection] = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            m = _SPEC_RE.match(part)
            if not m:
                raise ValueError(
                    f"bad injection spec {part!r} — expected "
                    f"kind:target@step[xduration][:factor], e.g. "
                    f"kill:w0.1@3 or slow:e1@5x3:4.0"
                )
            target = m.group("target")
            if target[0] == "w":
                if "." not in target:
                    raise ValueError(
                        f"worker target needs edge.worker, got {part!r}"
                    )
                e, w = target[1:].split(".")
                edge, worker = int(e), int(w)
            else:
                edge, worker = int(target[1:].split(".")[0]), None
            kw = {}
            if m.group("duration"):
                kw["duration"] = int(m.group("duration"))
            if m.group("factor"):
                kw["factor"] = float(m.group("factor"))
            out.append(Injection(kind=m.group("kind"),
                                 step=int(m.group("step")),
                                 edge=edge, worker=worker, **kw))
        return cls(out)

    @classmethod
    def seeded(cls, seed: int, topo: Topology, steps: int, *,
               n_events: int = 3, kinds: Sequence[str] = KINDS,
               max_kills: int = 1) -> "InjectionSchedule":
        """A random-but-reproducible schedule for soak tests.

        Kills are capped at ``max_kills`` single workers (never a whole
        edge) so a seeded soak stays inside one worker-tolerance level;
        slow/partition events target workers or edges freely.
        """
        rng = np.random.default_rng(np.random.SeedSequence([seed, 6271]))
        out: List[Injection] = []
        kills = 0
        for _ in range(n_events):
            kind = str(rng.choice(list(kinds)))
            if kind == KILL and kills >= max_kills:
                kind = SLOW
            step = int(rng.integers(1, max(steps - 2, 2)))
            edge = int(rng.integers(0, topo.n))
            worker: Optional[int] = int(rng.integers(0, topo.m[edge]))
            if kind != KILL and rng.random() < 0.3:
                worker = None  # pod-level event
            kw = {}
            if kind != KILL:
                kw["duration"] = int(rng.integers(1, 4))
            if kind == SLOW:
                kw["factor"] = float(np.round(rng.uniform(2.0, 6.0), 2))
            if kind == KILL:
                kills += 1
            out.append(Injection(kind=kind, step=step, edge=edge,
                                 worker=worker, **kw))
        return cls(out)

    def spec(self) -> str:
        return ",".join(x.spec for x in self.injections)

    def __len__(self) -> int:
        return len(self.injections)


class FailureInjector:
    """Evaluates the schedule against the episode's round counter."""

    def __init__(self, schedule: InjectionSchedule, topo: Topology):
        self.schedule = schedule
        self.topo = topo
        self.applied = 0

    def effects(self, step: int) -> RoundEffects:
        killed: set = set()
        partitioned: set = set()
        slow: Dict[int, float] = {}
        started: List[Injection] = []
        for inj in self.schedule.injections:
            if not inj.active(step):
                continue
            if inj.step == step:
                started.append(inj)
                self.applied += 1
            for flat in inj.targets(self.topo):
                if inj.kind == KILL:
                    killed.add(flat)
                elif inj.kind == PARTITION:
                    partitioned.add(flat)
                else:
                    slow[flat] = max(slow.get(flat, 1.0), inj.factor)
        return RoundEffects(killed=frozenset(killed),
                            partitioned=frozenset(partitioned),
                            slow=slow, started=tuple(started))
