"""Metrics layer — per-iteration JSONL sink + episode counters.

One JSON object per line, schema-versioned so downstream consumers
(``benchmarks/bench_orchestrator.py``, the CI gate, dashboards) can
parse blind.  Two record types share the stream:

``{"record": "iteration", ...}`` — one per training round::

    schema, step, clock_ms, loss, iter_ms,
    fast_e / fast_w          — the completion set the decode used
    n_results, n_counted     — responders vs workers inside the λ
    straggler_hit            — at least one live worker left out
    decode_ok                — probe-vector λ-decode matched Σ s_k
    heartbeat_misses         — deadline misses charged this round
    states                   — registry liveness census
    events                   — control-plane events this round
    wall_us                  — real master-side wall time (info only)

``{"record": "summary", ...}`` — one final line::

    schema, steps, counters{straggler_hits, replans, replan_errors,
    shrinks, heartbeat_misses, decode_fallbacks, injections_applied,
    flaps, rejoins}, jit_cache_entries, final_loss, episode_ms,
    detect_to_replan_ms      — first suspect/dead event -> first replan

Counters are monotone over the episode; ``iteration`` records carry the
*per-round* deltas so the stream integrates back to the summary.  The
sink buffers when constructed with ``path=None`` (tests, the bench) and
streams line-by-line otherwise (``flush`` per record — an episode that
dies mid-run still leaves parseable metrics).
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
from typing import Dict, List, Optional, Sequence

from repro.orchestrator import events as ev

METRICS_SCHEMA_VERSION = 1

# counter names are part of the schema — tests pin this tuple
COUNTERS = (
    "straggler_hits",
    "replans",
    "replan_errors",
    "shrinks",
    "heartbeat_misses",
    "decode_fallbacks",
    "injections_applied",
    "flaps",
    "rejoins",
)


class MetricsSink:
    """JSONL writer + the episode's counter block."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.counters: Dict[str, int] = {k: 0 for k in COUNTERS}
        self.records: List[Dict] = []
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            self._fh = open(path, "w", encoding="utf-8")

    # ------------------------------------------------------------------
    def bump(self, counter: str, by: int = 1) -> None:
        if counter not in self.counters:
            raise KeyError(
                f"unknown counter {counter!r}; schema v"
                f"{METRICS_SCHEMA_VERSION} counters are {COUNTERS}"
            )
        self.counters[counter] += by

    def _emit(self, record: Dict) -> None:
        self.records.append(record)
        if self._fh is not None:
            self._fh.write(json.dumps(record) + "\n")
            self._fh.flush()

    # ------------------------------------------------------------------
    def iteration(self, *, step: int, clock_ms: float, loss: float,
                  iter_ms: float, fast_e: Sequence[int],
                  fast_w: Sequence[Sequence[int]], n_results: int,
                  n_counted: int, straggler_hit: bool, decode_ok: bool,
                  heartbeat_misses: int, states: Dict[str, int],
                  round_events: Sequence[ev.Event],
                  wall_us: float) -> Dict:
        rec = {
            "record": "iteration",
            "schema": METRICS_SCHEMA_VERSION,
            "step": int(step),
            "clock_ms": round(float(clock_ms), 3),
            "loss": float(loss),
            "iter_ms": round(float(iter_ms), 3),
            "fast_e": [int(i) for i in fast_e],
            "fast_w": [[int(j) for j in w] for w in fast_w],
            "n_results": int(n_results),
            "n_counted": int(n_counted),
            "straggler_hit": bool(straggler_hit),
            "decode_ok": bool(decode_ok),
            "heartbeat_misses": int(heartbeat_misses),
            "states": dict(states),
            "events": [e.to_json() for e in round_events],
            "wall_us": round(float(wall_us), 1),
        }
        self._emit(rec)
        return rec

    def summary(self, *, steps: int, jit_cache_entries: int,
                final_loss: float, episode_ms: float,
                detect_to_replan_ms: Optional[float] = None,
                extra: Optional[Dict] = None) -> Dict:
        rec = {
            "record": "summary",
            "schema": METRICS_SCHEMA_VERSION,
            "steps": int(steps),
            "counters": dict(self.counters),
            "jit_cache_entries": int(jit_cache_entries),
            "final_loss": float(final_loss),
            "episode_ms": round(float(episode_ms), 3),
        }
        if detect_to_replan_ms is not None:
            rec["detect_to_replan_ms"] = round(float(detect_to_replan_ms), 3)
        if extra:
            rec.update(extra)
        self._emit(rec)
        return rec

    # ------------------------------------------------------------------
    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "MetricsSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_metrics(path: str) -> Dict[str, List[Dict]]:
    """Parse a metrics JSONL file into ``{"iteration": [...], "summary":
    [...]}`` — the helper the bench and the CI gate share.  Rejects
    records from a different schema version loudly rather than guessing.
    """
    out: Dict[str, List[Dict]] = {"iteration": [], "summary": []}
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            schema = rec.get("schema")
            if schema != METRICS_SCHEMA_VERSION:
                raise ValueError(
                    f"{path}:{lineno}: metrics schema {schema!r} != "
                    f"supported {METRICS_SCHEMA_VERSION}"
                )
            kind = rec.get("record")
            if kind not in out:
                raise ValueError(
                    f"{path}:{lineno}: unknown record type {kind!r}"
                )
            out[kind].append(rec)
    return out
