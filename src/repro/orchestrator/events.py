"""Control-plane events — the vocabulary of the liveness state machine.

Every registry transition, injection, and controller action is recorded
as one :class:`Event`; the controller consumes the stream to decide
replans and the metrics sink persists it (the JSONL ``events`` field).
Events are plain data — no callbacks, no threads — so episodes replay
deterministically and tests can assert on exact sequences.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

# ---- event kinds (stable strings: part of the metrics schema) --------
WORKER_JOINED = "worker_joined"
HEARTBEAT_MISSED = "heartbeat_missed"
WORKER_SUSPECT = "worker_suspect"
WORKER_DEAD = "worker_dead"
WORKER_RECOVERED = "worker_recovered"   # SUSPECT -> HEALTHY
WORKER_REJOINED = "worker_rejoined"     # DEAD -> HEALTHY (heal)
EDGE_DOWN = "edge_down"
EDGE_UP = "edge_up"
INJECTION = "injection"
DECODE_FALLBACK = "decode_fallback"
REPLAN = "replan"
REPLAN_FAILED = "replan_failed"
SHRINK = "shrink"

EVENT_KINDS = (
    WORKER_JOINED, HEARTBEAT_MISSED, WORKER_SUSPECT, WORKER_DEAD,
    WORKER_RECOVERED, WORKER_REJOINED, EDGE_DOWN, EDGE_UP, INJECTION,
    DECODE_FALLBACK, REPLAN, REPLAN_FAILED, SHRINK,
)


@dataclasses.dataclass(frozen=True)
class Event:
    """One control-plane occurrence on the episode's virtual clock.

    ``worker`` is the flat worker index (``Topology.flat_index``),
    ``edge`` the edge index; either may be ``None`` for cluster-level
    events.  ``detail`` carries kind-specific payload (all values
    JSON-serializable — the metrics sink writes events verbatim).
    """

    kind: str
    step: int
    clock_ms: float
    worker: Optional[int] = None
    edge: Optional[int] = None
    detail: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}")

    def to_json(self) -> Dict:
        d = {"kind": self.kind, "step": self.step,
             "clock_ms": round(float(self.clock_ms), 3)}
        if self.worker is not None:
            d["worker"] = int(self.worker)
        if self.edge is not None:
            d["edge"] = int(self.edge)
        if self.detail:
            d["detail"] = self.detail
        return d


class EventLog:
    """Append-only episode event record with step-window draining.

    The controller appends during a round and drains the new slice into
    that round's metrics record; ``of_kind`` serves tests and the bench
    (detection-to-replan latency = first ``worker_dead``/``suspect`` to
    first ``replan``).
    """

    def __init__(self):
        self.events: List[Event] = []
        self._drained = 0

    def append(self, event: Event) -> Event:
        self.events.append(event)
        return event

    def drain_new(self) -> List[Event]:
        """Events appended since the previous drain (one round's worth)."""
        new = self.events[self._drained:]
        self._drained = len(self.events)
        return new

    def of_kind(self, *kinds: str) -> List[Event]:
        return [e for e in self.events if e.kind in kinds]

    def first(self, *kinds: str) -> Optional[Event]:
        for e in self.events:
            if e.kind in kinds:
                return e
        return None

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.events)
