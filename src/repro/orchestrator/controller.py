"""Event-driven controller — the supervised coded training service.

The controller closes the loop the library pieces leave open: it owns a
:class:`~repro.orchestrator.workers.WorkerPool` of real worker
processes, a :class:`~repro.orchestrator.registry.DeviceRegistry` fed
by a :class:`~repro.orchestrator.heartbeat.HeartbeatMonitor`, a
:class:`~repro.orchestrator.injector.FailureInjector`, and ONE
:class:`~repro.api.session.CodedSession` whose compiled train step is
never rebuilt — the episode's whole point is that every fault the
injector throws is absorbed by runtime operands (the λ decode weights
and the tolerance), so ``session.jit_cache_entries()`` stays at 1.

One round:

  1. apply scheduled injections (kill/slow/partition),
  2. dispatch the round's :class:`WorkItem` to every live worker —
     each carries the worker's eq.-(22) coefficient row and assigned
     parts over a fresh probe vector,
  3. collect results; a partitioned worker's messages are dropped at
     the master (it computed — the control plane just never hears),
  4. select the completion set by the paper's wait rule — per edge the
     ``m_i − s_w^i`` fastest responders, the ``n − s_e`` edges with the
     smallest completion times — entirely from *reported* runtimes,
  5. verify the two-stage decode numerically on the probe partials
     (Σ λ_ij·ĝ_ij must equal Σ_k s_k) — ``decode_ok``,
  6. run the compiled train step under that completion set
     (:meth:`CodedSession.external_step`), feeding the detector the
     round's observation row,
  7. advance the virtual clock by the round's completion time, deliver
     the beats that have "arrived" by then (a straggler's beat is
     late → it flaps to SUSPECT and recovers on delivery), tick the
     heartbeat deadlines,
  8. translate this round's registry events into control actions:
     worker death / pod loss / decode fallback / rejoin → fit a fresh
     cluster model from the observation ledger
     (``CodedCluster.from_observations``) and ``session.replan`` on
     it; a structured :class:`~repro.api.session.ReplanError` is
     LOGGED (``replan_errors``), never fatal,
  9. emit the round's metrics record.

If too few edges can decode (below ``n − s_e`` selectable), the round
is a ``decode_fallback``: the model update is SKIPPED (λ would not
reconstruct the gradient), the observation still lands, and the
fallback itself triggers a replan toward a tolerance the surviving
cluster can honor.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.orchestrator import events as ev
from repro.orchestrator.heartbeat import (Heartbeat, HeartbeatConfig,
                                          HeartbeatMonitor)
from repro.orchestrator.injector import (KILL, FailureInjector,
                                         InjectionSchedule)
from repro.orchestrator.metrics import MetricsSink
from repro.orchestrator.registry import DeviceRegistry
from repro.orchestrator.workers import (PROBE_DIM, WorkerPool, WorkItem,
                                        probe_true_sum, rows_from_params)

# event kinds that make the controller consider replanning
_REPLAN_TRIGGERS = (ev.WORKER_DEAD, ev.EDGE_DOWN, ev.WORKER_REJOINED,
                    ev.EDGE_UP, ev.DECODE_FALLBACK)


@dataclasses.dataclass
class OrchestratorConfig:
    """Episode policy knobs (all deterministic)."""

    steps: int = 12
    backend: str = "auto"           # worker pool backend
    heartbeat: Optional[HeartbeatConfig] = None  # None: derive from plan
    replan_cooldown: int = 2        # min rounds between replan attempts
    min_obs_for_fit: int = 3        # observation rows before fitting
    fit_window: int = 12            # rows handed to from_observations
    probe_dim: int = PROBE_DIM
    collect_timeout_s: float = 60.0
    verbose: bool = False


def derive_heartbeat(expected_iteration_ms: float) -> HeartbeatConfig:
    """Deadline policy scaled to the plan's expected iteration time.

    A beat is owed roughly every iteration; the timeout passes only
    when a worker runs well beyond the planner's own T̂ estimate —
    so a "miss" means *slower than the plan priced*, not noise.
    """
    t = max(float(expected_iteration_ms), 1.0)
    return HeartbeatConfig(interval_ms=t, timeout_ms=2.5 * t)


class Orchestrator:
    """Runs one supervised episode over a live :class:`CodedSession`."""

    def __init__(self, session, config: Optional[OrchestratorConfig] = None,
                 *, schedule: Optional[InjectionSchedule] = None,
                 metrics: Optional[MetricsSink] = None):
        if session.cluster is None:
            raise ValueError("orchestrator needs a training session "
                             "(cluster=None is serve-only)")
        self.session = session
        self.config = config or OrchestratorConfig()
        topo = session.cluster.topo
        self.log = ev.EventLog()
        self.registry = DeviceRegistry(topo, self.log)
        self.registry.register_all(capabilities={
            f: {"c_ms_per_part": float(session.cluster.params.c[f])}
            for f in range(topo.total_workers)
        })
        hb = self.config.heartbeat or derive_heartbeat(
            session.plan.expected_iteration_ms
            if session.plan is not None
            and session.plan.expected_iteration_ms is not None
            else 500.0
        )
        self.monitor = HeartbeatMonitor(self.registry, hb)
        self.injector = FailureInjector(
            schedule or InjectionSchedule(), topo)
        self.pool = WorkerPool(
            topo, rows_from_params(session.cluster.params),
            seed=session.seed, backend=self.config.backend,
            probe_dim=self.config.probe_dim)
        self.metrics = metrics or MetricsSink()
        self.clock_ms = 0.0
        self._pending_beats: List[Heartbeat] = []
        self._killed_at: Dict[int, float] = {}
        self._last_replan_round = -(10 ** 9)
        self._round = 0

    # ------------------------------------------------------------------
    # completion-set selection (the paper's wait rule, from reports)
    # ------------------------------------------------------------------
    def select_completion_set(self, runtimes: Dict[int, float]):
        """HGC wait rule over REPORTED runtimes.

        Per edge, the fastest ``m_i − s_w^i`` responders; an edge with
        fewer responders cannot decode and is unselectable; the
        ``n − s_e`` selectable edges with the smallest completion times
        win.  Returns ``(fast_e, fast_w, iter_ms)`` or ``None`` when
        fewer than ``n − s_e`` edges can decode (decode fallback).

        Edge upload times are drawn master-side from the cluster model
        (the worker totals cover compute + both link hops below the
        edge; the edge→master hop is the edge's own).
        """
        code = self.session.code
        topo = self.session.cluster.topo
        params = self.session.cluster.params
        rng = np.random.default_rng(np.random.SeedSequence(
            [self.session.seed, 7919, self._round]))
        n_up = rng.geometric(1.0 - np.asarray(params.p_e))
        edge_up = n_up * np.asarray(params.tau_e)

        fast_w: List[Tuple[int, ...]] = []
        edge_T = np.full(topo.n, np.inf)
        for i in range(topo.n):
            need = topo.m[i] - code.tol.s_w_of(i)
            have = [(runtimes[topo.flat_index(i, j)], j)
                    for j in range(topo.m[i])
                    if topo.flat_index(i, j) in runtimes]
            if len(have) < need:
                fast_w.append(())
                continue
            have.sort()
            chosen = have[:need]
            fast_w.append(tuple(sorted(j for _, j in chosen)))
            edge_T[i] = edge_up[i] + max(t for t, _ in chosen)
        need_e = topo.n - code.tol.s_e
        order = np.argsort(edge_T)
        if not np.isfinite(edge_T[order[need_e - 1]]):
            return None
        fast_e = tuple(sorted(int(i) for i in order[:need_e]))
        return fast_e, fast_w, float(edge_T[order[need_e - 1]])

    # ------------------------------------------------------------------
    def _probe_decode_ok(self, results, fast_e, fast_w, probe_seed) -> bool:
        """Numeric end-to-end check of the two-stage λ decode."""
        code = self.session.code
        topo = self.session.cluster.topo
        lam = code.collapsed_weights(fast_e, fast_w)
        decoded = np.zeros(self.config.probe_dim)
        for f, r in results.items():
            if lam[f] != 0.0:
                decoded += lam[f] * r.partial
        truth = probe_true_sum(probe_seed, code.K, self.config.probe_dim)
        return bool(np.allclose(decoded, truth, rtol=1e-6, atol=1e-8))

    def _deliver_due_beats(self, step: int) -> None:
        """Deliver held-back beats whose virtual send time has passed."""
        due = [b for b in self._pending_beats
               if b.sent_ms <= self.clock_ms]
        self._pending_beats = [b for b in self._pending_beats
                               if b.sent_ms > self.clock_ms]
        for b in sorted(due, key=lambda b: (b.sent_ms, b.flat)):
            self.monitor.deliver(b, step)

    # ------------------------------------------------------------------
    def _maybe_replan(self, step: int, round_events) -> None:
        """Registry transitions → fit-from-observations → replan."""
        if not any(e.kind in _REPLAN_TRIGGERS for e in round_events):
            return
        if self._round - self._last_replan_round < self.config.replan_cooldown:
            return
        if len(self.monitor.rows) < self.config.min_obs_for_fit:
            return
        self._last_replan_round = self._round
        sess = self.session
        code = sess.code
        D_ref = float(np.mean(getattr(code, "load_array", code.load)))
        from repro.api.session import ReplanError

        try:
            fitted = self.monitor.fit_cluster(
                D_ref, window=self.config.fit_window,
                alpha=sess.cluster.alpha)
            old_tol = (code.tol.s_e, code.tol.s_w)
            plan = sess.replan(cluster=fitted)
            self.metrics.bump("replans")
            self.log.append(ev.Event(
                kind=ev.REPLAN, step=step, clock_ms=self.clock_ms,
                detail={
                    "old_tol": list(old_tol),
                    "new_tol": [plan.tol.s_e, plan.tol.s_w],
                    "K": plan.K,
                    "changed": plan.code is not code,
                },
            ))
            if self.config.verbose:
                print(f"[orch] replan @ step {step}: tol {old_tol} -> "
                      f"({plan.tol.s_e}, {plan.tol.s_w}), K={plan.K}")
        except ReplanError as err:
            # structured failure: the constraint that broke and the
            # surviving topology ride the event; the episode continues
            # on the old plan
            self.metrics.bump("replan_errors")
            self.log.append(ev.Event(
                kind=ev.REPLAN_FAILED, step=step, clock_ms=self.clock_ms,
                detail={"constraint": err.constraint,
                        "m": list(err.topo.m), "error": str(err)},
            ))
            if self.config.verbose:
                print(f"[orch] replan failed @ step {step} "
                      f"({err.constraint}): {err}")

    # ------------------------------------------------------------------
    def run_round(self, step: int) -> Dict:
        """One supervised round; returns the iteration metrics record."""
        cfg = self.config
        sess = self.session
        code = sess.code
        topo = sess.cluster.topo
        t0 = time.perf_counter()

        # 1. injections
        effects = self.injector.effects(self._round)
        for inj in effects.started:
            self.metrics.bump("injections_applied")
            self.log.append(ev.Event(
                kind=ev.INJECTION, step=step, clock_ms=self.clock_ms,
                edge=inj.edge, worker=(
                    None if inj.worker is None
                    else topo.flat_index(inj.edge, inj.worker)),
                detail=inj.to_json(),
            ))
            if inj.kind == KILL:
                for f in inj.targets(topo):
                    self.pool.kill(f)
                    # virtual-time consistency: a message "sent" after
                    # the kill instant is from a computation the dead
                    # worker never finished — it must not resurrect it
                    self._killed_at[f] = self.clock_ms
                self._pending_beats = [
                    b for b in self._pending_beats
                    if not (b.flat in self._killed_at
                            and b.sent_ms > self._killed_at[b.flat])
                ]

        # 2. dispatch the round to every live worker
        probe_seed = int(np.random.SeedSequence(
            [sess.seed, 15485863, self._round]).generate_state(1)[0])
        load_arr = getattr(code, "load_array", None)
        expected: Set[int] = set()
        for i in range(topo.n):
            for j in range(topo.m[i]):
                f = topo.flat_index(i, j)
                D = float(load_arr[f]) if load_arr is not None \
                    else float(code.load)
                ok = self.pool.dispatch(f, WorkItem(
                    step=self._round, clock_ms=self.clock_ms,
                    coeffs=np.asarray(code.worker_coeffs(i, j)),
                    parts=tuple(code.assignment.worker_parts(i, j)),
                    D=D, probe_seed=probe_seed, probe_dim=cfg.probe_dim,
                    slow_factor=effects.slow_factor(f),
                ))
                if ok:
                    expected.add(f)

        # 3. collect; partition drops messages AT THE MASTER
        raw = self.pool.collect(self._round, expected,
                                timeout_s=cfg.collect_timeout_s)
        results = {f: r for f, r in raw.items()
                   if f not in effects.partitioned}

        # 4. completion set by the wait rule
        runtimes = {f: r.runtime_ms for f, r in results.items()}
        sel = self.select_completion_set(runtimes)

        # 5./6. decode check + the compiled train step
        decode_ok = False
        loss = float("nan")
        n_counted = 0
        if sel is not None:
            fast_e, fast_w, iter_ms = sel
            decode_ok = self._probe_decode_ok(
                results, fast_e, fast_w, probe_seed)
            n_counted = sum(len(fast_w[i]) for i in fast_e)
            totals = {f: r.runtime_ms for f, r in results.items()}
            obs_row = self.monitor.record_round(totals)
            m = sess.external_step(fast_e, fast_w,
                                   worker_totals=obs_row,
                                   sim_iter_ms=iter_ms)
            loss = float(m["loss"])
        else:
            # decode fallback: no λ reconstructs the gradient — skip
            # the update, keep the observation, trigger a replan
            self.metrics.bump("decode_fallbacks")
            iter_ms = (max(runtimes.values())
                       if runtimes else self.monitor.config.timeout_ms)
            fast_e, fast_w = (), []
            totals = {f: r.runtime_ms for f, r in results.items()}
            obs_row = self.monitor.record_round(totals)
            sess.cluster.observe(obs_row)
            self.log.append(ev.Event(
                kind=ev.DECODE_FALLBACK, step=step,
                clock_ms=self.clock_ms,
                detail={"responders": len(results),
                        "need_edges": topo.n - code.tol.s_e},
            ))

        straggler_hit = len(results) > n_counted
        if straggler_hit and sel is not None:
            self.metrics.bump("straggler_hits")

        # 7. clock advance + beat delivery + deadline tick
        self.clock_ms += iter_ms
        for f, r in sorted(results.items()):
            if f in self._killed_at and r.sent_ms > self._killed_at[f]:
                continue
            self._pending_beats.append(Heartbeat(
                flat=f, sent_ms=r.sent_ms, runtime_ms=r.runtime_ms))
        self._deliver_due_beats(step)
        misses = self.monitor.tick(step, self.clock_ms)
        if misses:
            self.metrics.bump("heartbeat_misses", misses)

        # 8. events → control actions
        round_events = self.log.drain_new()
        for e in round_events:
            if e.kind == ev.WORKER_RECOVERED:
                self.metrics.bump("flaps")
            elif e.kind == ev.WORKER_REJOINED:
                self.metrics.bump("rejoins")
        self._maybe_replan(step, round_events)
        round_events += self.log.drain_new()  # replan/replan_failed

        # 9. metrics
        rec = self.metrics.iteration(
            step=step, clock_ms=self.clock_ms, loss=loss,
            iter_ms=iter_ms, fast_e=fast_e, fast_w=fast_w,
            n_results=len(results), n_counted=n_counted,
            straggler_hit=straggler_hit, decode_ok=decode_ok,
            heartbeat_misses=misses, states=self.registry.counts(),
            round_events=round_events,
            wall_us=(time.perf_counter() - t0) * 1e6,
        )
        self._round += 1
        return rec

    # ------------------------------------------------------------------
    def run_episode(self, steps: Optional[int] = None) -> Dict:
        """Run the supervised episode; returns the summary record."""
        n = steps if steps is not None else self.config.steps
        started_here = not self.pool._started
        if started_here:
            self.pool.start()
        try:
            for _ in range(n):
                step = self.session._step
                rec = self.run_round(step)
                if self.config.verbose:
                    print(f"[orch] step {step} loss {rec['loss']:.4f} "
                          f"iter {rec['iter_ms']:.0f} ms "
                          f"counted {rec['n_counted']}/{rec['n_results']} "
                          f"states {rec['states']}")
        finally:
            if started_here:
                self.pool.close()
        return self.finalize(n)

    def finalize(self, steps: int) -> Dict:
        """Write the episode summary record."""
        detect = self.log.first(ev.WORKER_SUSPECT, ev.WORKER_DEAD,
                                ev.EDGE_DOWN)
        replan = self.log.first(ev.REPLAN)
        d2r = (replan.clock_ms - detect.clock_ms
               if detect is not None and replan is not None
               and replan.clock_ms >= detect.clock_ms else None)
        losses = self.session.losses
        summary = self.metrics.summary(
            steps=steps,
            jit_cache_entries=self.session.jit_cache_entries(),
            final_loss=float(losses[-1]) if losses else float("nan"),
            episode_ms=self.clock_ms,
            detect_to_replan_ms=d2r,
            extra={
                "injections": [x.to_json()
                               for x in self.injector.schedule.injections],
                "event_counts": self.log.counts(),
                "backend": self.pool.backend,
            },
        )
        self.metrics.close()
        return summary
