"""Orchestrator — the coded training loop as a supervised service.

Public surface::

    from repro.orchestrator import (
        DeviceRegistry, HeartbeatMonitor, HeartbeatConfig,
        InjectionSchedule, FailureInjector, WorkerPool,
        Orchestrator, OrchestratorConfig, MetricsSink, read_metrics,
        EventLog,
    )

Imports here are LAZY on purpose: spawned worker processes import
``repro.orchestrator.workers`` (numpy-only) through this package, and
must never pay for — or race — the controller's jax import.
"""
from __future__ import annotations

_EXPORTS = {
    "Event": "repro.orchestrator.events",
    "EventLog": "repro.orchestrator.events",
    "DeviceRegistry": "repro.orchestrator.registry",
    "WorkerRecord": "repro.orchestrator.registry",
    "Heartbeat": "repro.orchestrator.heartbeat",
    "HeartbeatConfig": "repro.orchestrator.heartbeat",
    "HeartbeatMonitor": "repro.orchestrator.heartbeat",
    "Injection": "repro.orchestrator.injector",
    "InjectionSchedule": "repro.orchestrator.injector",
    "FailureInjector": "repro.orchestrator.injector",
    "RoundEffects": "repro.orchestrator.injector",
    "ModelRow": "repro.orchestrator.workers",
    "WorkItem": "repro.orchestrator.workers",
    "WorkerPool": "repro.orchestrator.workers",
    "rows_from_params": "repro.orchestrator.workers",
    "MetricsSink": "repro.orchestrator.metrics",
    "read_metrics": "repro.orchestrator.metrics",
    "METRICS_SCHEMA_VERSION": "repro.orchestrator.metrics",
    "Orchestrator": "repro.orchestrator.controller",
    "OrchestratorConfig": "repro.orchestrator.controller",
    "derive_heartbeat": "repro.orchestrator.controller",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib

        mod = importlib.import_module(_EXPORTS[name])
        val = getattr(mod, name)
        globals()[name] = val
        return val
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
