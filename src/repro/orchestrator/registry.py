"""Device registry — worker/edge identity, capability, liveness.

The paper's cluster is a static tree; a real deployment is not.  The
registry is the control plane's single source of truth about *who is
currently in the tree*: every worker has an identity (its (edge,
worker) slot and flat index), a capability record (the per-part compute
rate it advertised at join), and a liveness state driven by heartbeats:

    JOINING ──beat──► HEALTHY ──deadline miss──► SUSPECT ──more──► DEAD
       │                 ▲                        ▲ │                │
       └─ join grace ────┼─── expires (miss) ─────┘ │                │
                         └────────── beat ──────────┘                │
                         └───────────────── beat (heal) ─────────────┘

``SUSPECT -> HEALTHY`` is a recovery (a flap: the worker missed a
deadline but beat again inside the death budget); ``DEAD -> HEALTHY``
is a rejoin (a healed partition — the *process* may be fine even though
liveness declared it gone).  A worker that never delivers its FIRST
beat takes the ``JOINING -> SUSPECT -> DEAD`` path once the (wider)
join grace deadline expires — a worker killed before it ever reported
must still be detectable.  All transitions emit :mod:`events` so the
controller can translate them into replans; the registry itself never
touches the session.

Edge (pod) liveness is derived: an edge is down when none of its
workers are HEALTHY/JOINING — the registry emits ``edge_down`` /
``edge_up`` on the boundary crossings so a pod-level failure is one
event, not ``m_i`` separate ones.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.topology import Topology
from repro.orchestrator import events as ev

# liveness states (stable strings — part of the metrics schema)
JOINING = "JOINING"
HEALTHY = "HEALTHY"
SUSPECT = "SUSPECT"
DEAD = "DEAD"
STATES = (JOINING, HEALTHY, SUSPECT, DEAD)

# legal transitions of the liveness machine; anything else is a bug in
# the caller and raises instead of silently corrupting the registry
_TRANSITIONS = {
    (JOINING, HEALTHY),
    (JOINING, SUSPECT),   # join grace expired without a first beat
    (HEALTHY, SUSPECT),
    (SUSPECT, HEALTHY),
    (SUSPECT, DEAD),
    (DEAD, HEALTHY),
}


@dataclasses.dataclass
class WorkerRecord:
    """One worker's registry row."""

    flat: int
    edge: int
    worker: int
    capability: Dict = dataclasses.field(default_factory=dict)
    state: str = JOINING
    last_beat_ms: float = 0.0
    consecutive_misses: int = 0
    joined_step: int = 0
    deaths: int = 0

    @property
    def live(self) -> bool:
        """Counted as a submission candidate (JOINING workers have not
        produced work yet; SUSPECT workers may still submit)."""
        return self.state in (HEALTHY, SUSPECT)

    def to_json(self) -> Dict:
        return {
            "flat": self.flat, "edge": self.edge, "worker": self.worker,
            "state": self.state, "misses": self.consecutive_misses,
            "deaths": self.deaths,
        }


class DeviceRegistry:
    """Liveness state machine over a :class:`~repro.core.topology.Topology`.

    The registry is indexed by FLAT worker id (``topo.flat_index``);
    the (edge, worker) slot of each record is fixed — the control plane
    never renumbers (renumbering is what ``CodedSession.shrink`` does,
    and that is a topology change, not a liveness change).
    """

    def __init__(self, topo: Topology, log: Optional[ev.EventLog] = None):
        self.topo = topo
        self.log = log if log is not None else ev.EventLog()
        self.workers: Dict[int, WorkerRecord] = {}
        self._edge_down: Dict[int, bool] = {i: False for i in range(topo.n)}

    # ------------------------------------------------------------------
    # membership
    # ------------------------------------------------------------------
    def register(self, edge: int, worker: int, *, step: int = 0,
                 capability: Optional[Dict] = None) -> WorkerRecord:
        flat = self.topo.flat_index(edge, worker)
        if flat in self.workers:
            raise ValueError(f"worker ({edge}, {worker}) already registered")
        rec = WorkerRecord(flat=flat, edge=edge, worker=worker,
                           capability=dict(capability or {}),
                           joined_step=step)
        self.workers[flat] = rec
        return rec

    def register_all(self, *, step: int = 0,
                     capabilities: Optional[Dict[int, Dict]] = None) -> None:
        for (i, j) in self.topo.worker_ids():
            flat = self.topo.flat_index(i, j)
            self.register(i, j, step=step,
                          capability=(capabilities or {}).get(flat))

    def record(self, flat: int) -> WorkerRecord:
        return self.workers[flat]

    # ------------------------------------------------------------------
    # transitions
    # ------------------------------------------------------------------
    def _transition(self, rec: WorkerRecord, new: str, step: int,
                    clock_ms: float, kind: str, **detail) -> None:
        if (rec.state, new) not in _TRANSITIONS:
            raise ValueError(
                f"illegal liveness transition {rec.state} -> {new} for "
                f"worker {rec.flat}"
            )
        rec.state = new
        self.log.append(ev.Event(
            kind=kind, step=step, clock_ms=clock_ms, worker=rec.flat,
            edge=rec.edge, detail=detail or {},
        ))
        self._check_edge(rec.edge, step, clock_ms)

    def beat(self, flat: int, step: int, clock_ms: float) -> None:
        """A heartbeat arrived: reset the miss budget, maybe recover."""
        rec = self.workers[flat]
        rec.last_beat_ms = clock_ms
        rec.consecutive_misses = 0
        if rec.state == JOINING:
            self._transition(rec, HEALTHY, step, clock_ms,
                             ev.WORKER_JOINED)
        elif rec.state == SUSPECT:
            self._transition(rec, HEALTHY, step, clock_ms,
                             ev.WORKER_RECOVERED)
        elif rec.state == DEAD:
            rec.deaths = rec.deaths  # rejoin keeps the death count
            self._transition(rec, HEALTHY, step, clock_ms,
                             ev.WORKER_REJOINED)

    def miss(self, flat: int, step: int, clock_ms: float, *,
             suspect_after: int, dead_after: int) -> None:
        """A heartbeat deadline passed without a beat."""
        rec = self.workers[flat]
        if rec.state == DEAD:
            return
        rec.consecutive_misses += 1
        self.log.append(ev.Event(
            kind=ev.HEARTBEAT_MISSED, step=step, clock_ms=clock_ms,
            worker=rec.flat, edge=rec.edge,
            detail={"misses": rec.consecutive_misses},
        ))
        if rec.state in (HEALTHY, JOINING) \
                and rec.consecutive_misses >= suspect_after:
            self._transition(rec, SUSPECT, step, clock_ms,
                             ev.WORKER_SUSPECT,
                             misses=rec.consecutive_misses)
        elif rec.state == SUSPECT and rec.consecutive_misses >= dead_after:
            rec.deaths += 1
            self._transition(rec, DEAD, step, clock_ms, ev.WORKER_DEAD,
                             misses=rec.consecutive_misses)

    def _check_edge(self, edge: int, step: int, clock_ms: float) -> None:
        """Derived pod liveness: emit edge_down/up on boundary crossings."""
        regs = [r for r in self.workers.values() if r.edge == edge]
        down = bool(regs) and all(r.state == DEAD for r in regs)
        if down and not self._edge_down[edge]:
            self._edge_down[edge] = True
            self.log.append(ev.Event(
                kind=ev.EDGE_DOWN, step=step, clock_ms=clock_ms,
                edge=edge, detail={"workers": len(regs)},
            ))
        elif not down and self._edge_down[edge]:
            self._edge_down[edge] = False
            self.log.append(ev.Event(
                kind=ev.EDGE_UP, step=step, clock_ms=clock_ms, edge=edge,
            ))

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def state_of(self, flat: int) -> str:
        return self.workers[flat].state

    def live_workers(self) -> List[int]:
        return sorted(f for f, r in self.workers.items() if r.live)

    def dead_workers(self) -> List[int]:
        return sorted(f for f, r in self.workers.items()
                      if r.state == DEAD)

    def edge_down(self, edge: int) -> bool:
        return self._edge_down[edge]

    def down_edges(self) -> List[int]:
        return sorted(i for i, d in self._edge_down.items() if d)

    def counts(self) -> Dict[str, int]:
        out = {s: 0 for s in STATES}
        for r in self.workers.values():
            out[r.state] += 1
        return out

    def to_json(self) -> Dict:
        return {
            "m": list(self.topo.m),
            "workers": [self.workers[f].to_json()
                        for f in sorted(self.workers)],
            "down_edges": self.down_edges(),
        }
