"""Heartbeat monitor — liveness deadlines + the observation ledger.

Two jobs, one tick:

  * **liveness** — each live worker owes a beat every ``interval_ms``
    on the episode's virtual clock; a worker whose silence exceeds
    ``timeout_ms × backoff^misses`` is missed (the backoff widens the
    deadline for already-suspect workers so one slow link does not
    escalate straight to DEAD), and the registry's state machine turns
    consecutive misses into SUSPECT/DEAD transitions,
  * **observation** — every beat carries the worker's last per-iteration
    total (an eq.-31 sample); the monitor keeps a per-worker EWMA *and*
    the full per-round rows, because the two consumers want different
    things: the EWMA fills the rows of silent workers (a dead worker
    still occupies a row — its staleness is exactly what the fit should
    see as "slow"), and the complete row matrix is what
    :meth:`fit_cluster` hands to ``CodedCluster.from_observations`` to
    close the paper's fit-replan loop from *measured* delays.

The monitor never touches wall time: the controller advances the
virtual clock by each round's simulated iteration time, so tests and
CI replay byte-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.topology import Topology
from repro.orchestrator.registry import DEAD, JOINING, DeviceRegistry


@dataclasses.dataclass(frozen=True)
class HeartbeatConfig:
    """Deadline policy on the virtual clock (all times in ms).

    ``suspect_after``/``dead_after`` are CONSECUTIVE missed deadlines:
    with the defaults a worker is SUSPECT after its first miss and DEAD
    after three, each deadline ``backoff×`` wider than the last.
    ``miss_fill_factor`` scales the observation filled in for a silent
    worker (relative to its EWMA / the round's slowest responder) so
    the cluster fit sees silence as slowness.
    """

    interval_ms: float = 100.0
    timeout_ms: float = 300.0
    backoff: float = 1.5
    suspect_after: int = 1
    dead_after: int = 3
    miss_fill_factor: float = 2.0
    join_grace_factor: float = 4.0

    def __post_init__(self):
        if self.interval_ms <= 0 or self.timeout_ms <= 0:
            raise ValueError("heartbeat interval/timeout must be > 0")
        if self.join_grace_factor < 1.0:
            raise ValueError("join_grace_factor must be >= 1.0")
        if self.timeout_ms < self.interval_ms:
            raise ValueError(
                f"timeout_ms={self.timeout_ms} below interval_ms="
                f"{self.interval_ms} — every beat would be late"
            )
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1.0")
        if not (0 < self.suspect_after <= self.dead_after):
            raise ValueError(
                "need 0 < suspect_after <= dead_after misses"
            )


@dataclasses.dataclass(frozen=True)
class Heartbeat:
    """One beat: worker identity + its latest runtime observation."""

    flat: int
    sent_ms: float
    runtime_ms: Optional[float] = None  # eq.-31 total of the last round


class HeartbeatMonitor:
    """Deadline evaluation + EWMA runtime ledger over the registry."""

    def __init__(self, registry: DeviceRegistry,
                 config: Optional[HeartbeatConfig] = None, *,
                 ewma_alpha: float = 0.3):
        self.registry = registry
        self.config = config or HeartbeatConfig()
        self.ewma_alpha = float(ewma_alpha)
        self.ewma: Dict[int, float] = {}
        self.rows: List[np.ndarray] = []   # complete per-round obs rows
        self.misses_total = 0
        self.beats_total = 0

    @property
    def topo(self) -> Topology:
        return self.registry.topo

    # ------------------------------------------------------------------
    def deliver(self, beat: Heartbeat, step: int) -> None:
        """Process one beat (registry transition + EWMA update).

        Safe at ANY time — including while a replan is in flight: the
        monitor only mutates its own ledger and the registry row, never
        the session, so a beat that races a replan lands in the next
        round's deadline evaluation instead of corrupting anything.
        """
        self.beats_total += 1
        self.registry.beat(beat.flat, step, beat.sent_ms)
        if beat.runtime_ms is not None:
            prev = self.ewma.get(beat.flat)
            self.ewma[beat.flat] = (
                float(beat.runtime_ms) if prev is None
                else (1 - self.ewma_alpha) * prev
                + self.ewma_alpha * float(beat.runtime_ms)
            )

    def tick(self, step: int, now_ms: float) -> int:
        """Evaluate deadlines at virtual time ``now_ms``; returns the
        number of misses charged this tick."""
        cfg = self.config
        missed = 0
        for flat, rec in sorted(self.registry.workers.items()):
            if rec.state == DEAD:
                continue
            if rec.state == JOINING and rec.consecutive_misses == 0:
                # a worker that never beat yet gets the (wider) join
                # grace before its first miss — slow first rounds are
                # normal on a heterogeneous edge, silence forever not
                deadline = cfg.timeout_ms * cfg.join_grace_factor
            else:
                deadline = cfg.timeout_ms * (
                    cfg.backoff ** rec.consecutive_misses)
            if now_ms - rec.last_beat_ms > deadline:
                self.registry.miss(
                    flat, step, now_ms,
                    suspect_after=cfg.suspect_after,
                    dead_after=cfg.dead_after,
                )
                missed += 1
        self.misses_total += missed
        return missed

    # ------------------------------------------------------------------
    # the observation ledger
    # ------------------------------------------------------------------
    def record_round(self, totals: Dict[int, float]) -> np.ndarray:
        """Close one round's observation row.

        ``totals`` maps flat worker index → observed eq.-31 total for
        the workers that responded; silent workers are filled with
        ``miss_fill_factor ×`` their EWMA (or the round's slowest
        responder when no history exists) — a conservative "at least
        this slow" that keeps the fit matrix rectangular and makes
        persistent silence look persistently slow.
        """
        W = self.topo.total_workers
        row = np.empty(W, np.float64)
        responded = [t for t in totals.values() if t is not None]
        slowest = max(responded) if responded else self.config.timeout_ms
        for flat in range(W):
            t = totals.get(flat)
            if t is None:
                base = self.ewma.get(flat, slowest)
                t = self.config.miss_fill_factor * base
            row[flat] = float(t)
            prev = self.ewma.get(flat)
            self.ewma[flat] = (
                row[flat] if prev is None
                else (1 - self.ewma_alpha) * prev
                + self.ewma_alpha * row[flat]
            )
        self.rows.append(row)
        return row

    def observation_matrix(self, window: int = 0) -> np.ndarray:
        """(rounds × W) matrix of the last ``window`` rows (0 = all)."""
        rows = self.rows[-window:] if window else self.rows
        if not rows:
            return np.empty((0, self.topo.total_workers))
        return np.stack(rows, axis=0)

    def fit_cluster(self, D: float, *, window: int = 0, **priors):
        """Fit a fresh ``CodedCluster`` from the observed rows.

        The fit-replan loop's closing move: per-worker compute rates are
        fitted so the model's expected eq.-31 totals match the observed
        means at load ``D`` (``CodedCluster.from_observations``), and
        the returned cluster's detector is warm-started with the same
        rows — the next planner pass prices *measured* delays.
        """
        from repro.api.cluster import CodedCluster

        obs = self.observation_matrix(window)
        if obs.shape[0] == 0:
            raise ValueError("no observation rows recorded yet")
        return CodedCluster.from_observations(self.topo, obs, D, **priors)
