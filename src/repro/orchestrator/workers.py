"""Worker pool — real concurrent workers under the control plane.

Each worker of the topology runs as its own OS process (or thread,
where the runner lacks cores) and, per round, performs the *worker side
of eq. (22)*: it receives its coded coefficient row and assigned parts,
computes the encoded partial over a probe vector per part, draws its
iteration runtime from its own slice of the runtime model (eq. 31 —
compute + worker-link + edge-download terms, all seeded by
``(seed, worker, step)`` so every backend replays identically), and
submits a :class:`Result` whose embedded heartbeat is stamped with the
VIRTUAL completion time ``dispatch clock + runtime``.

That stamp is the trick that makes the control plane honest without
wall-clock flakiness: a worker whose simulated round ran long delivers
a heartbeat that is genuinely *late* on the episode clock — the monitor
sees a missed deadline, the registry flaps it to SUSPECT, and its
recovery on the next round exercises the same state-machine path a real
deployment would, deterministically.

Workers never import jax: the gradient step stays on the master (the
compiled coded train step); what the pool distributes is the encoded
per-worker computation and the runtime/liveness ground truth the
orchestrator decodes and plans from.  The probe partials flow through
the SAME λ the train step consumes, so every round carries an
end-to-end numeric check of the two-stage decode under the live
completion set (``decode_ok``).
"""
from __future__ import annotations

import dataclasses
import os
import queue as queue_lib
import threading
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.topology import Topology

PROBE_DIM = 32


@dataclasses.dataclass(frozen=True)
class ModelRow:
    """One worker's slice of the cluster runtime model (priors or fit)."""

    c: float          # per-part compute ms
    gamma: float      # exponential noise rate
    tau_w: float      # worker-link delay ms
    p_w: float        # worker-link loss probability
    tau_e: float      # edge-link delay ms (download hop)
    p_e: float        # edge-link loss probability


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One round's assignment for one worker."""

    step: int
    clock_ms: float          # virtual dispatch time
    coeffs: np.ndarray       # (K,) effective coded coefficients
    parts: Tuple[int, ...]   # assigned global part ids
    D: float                 # per-worker load (parts per iteration)
    probe_seed: int
    probe_dim: int = PROBE_DIM
    slow_factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class Result:
    """One worker's round submission (result + piggybacked beat)."""

    flat: int
    step: int
    runtime_ms: float        # simulated eq.-31 total (slow-factor applied)
    sent_ms: float           # virtual completion time (the beat stamp)
    partial: np.ndarray      # encoded probe partial  Σ_k coeffs[k]·s_k
    wall_us: float           # real compute wall time (metrics only)


def probe_part_vector(probe_seed: int, k: int, dim: int) -> np.ndarray:
    """The deterministic probe "gradient" of part ``k`` this round."""
    rng = np.random.default_rng(np.random.SeedSequence([probe_seed, k]))
    return rng.standard_normal(dim)


def probe_true_sum(probe_seed: int, K: int, dim: int) -> np.ndarray:
    """Σ_k s_k — what an exact decode of the partials must recover."""
    out = np.zeros(dim)
    for k in range(K):
        out += probe_part_vector(probe_seed, k, dim)
    return out


def draw_runtime_ms(row: ModelRow, flat: int, step: int, seed: int,
                    D: float, slow_factor: float = 1.0) -> float:
    """Eq.-31 sample for one worker, seeded by (seed, worker, step).

    Mirrors ``ClusterParams.sample_iteration`` per worker (compute +
    2 worker-link transfers + the edge download hop); the injected
    ``slow_factor`` scales the deterministic compute term — a slow
    *device*, not a lossy link.
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, 104729, flat, step])
    )
    t_cmp = row.c * D * slow_factor + rng.exponential(1.0 / row.gamma)
    n_dl = rng.geometric(1.0 - row.p_w)
    n_ul = rng.geometric(1.0 - row.p_w)
    n_edl = rng.geometric(1.0 - row.p_e)
    return float(n_edl * row.tau_e + (n_dl + n_ul) * row.tau_w + t_cmp)


def _worker_main(flat: int, row: ModelRow, seed: int, inbox, outbox):
    """The worker loop (runs in a child process or thread).

    numpy-only on purpose: process children must never pay (or race)
    the jax import — the compiled model step is the master's job.
    """
    while True:
        msg = inbox.get()
        if msg[0] == "stop":
            return
        work: WorkItem = msg[1]
        t0 = time.perf_counter()
        runtime = draw_runtime_ms(row, flat, work.step, seed, work.D,
                                  work.slow_factor)
        partial = np.zeros(work.probe_dim)
        for k in work.parts:
            partial += work.coeffs[k] * probe_part_vector(
                work.probe_seed, k, work.probe_dim
            )
        outbox.put(("result", Result(
            flat=flat, step=work.step, runtime_ms=runtime,
            sent_ms=work.clock_ms + runtime, partial=partial,
            wall_us=(time.perf_counter() - t0) * 1e6,
        )))


def resolve_backend(backend: str = "auto") -> str:
    """``auto`` uses processes when the runner has cores to spare."""
    if backend not in ("auto", "process", "thread"):
        raise ValueError(f"unknown worker backend {backend!r}")
    if backend != "auto":
        return backend
    return "process" if (os.cpu_count() or 1) >= 2 else "thread"


class WorkerPool:
    """N workers as OS processes (or threads) + the message plumbing.

    One inbox queue per worker, one shared outbox.  ``kill`` terminates
    the worker for good (process SIGTERM / thread poison) — the control
    plane is NOT told, by design: death must be *detected* via missed
    heartbeats, that is the point of the monitor.
    """

    def __init__(self, topo: Topology, rows: Sequence[ModelRow], *,
                 seed: int = 0, backend: str = "auto",
                 probe_dim: int = PROBE_DIM):
        if len(rows) != topo.total_workers:
            raise ValueError(
                f"need one ModelRow per worker "
                f"({topo.total_workers}), got {len(rows)}"
            )
        self.topo = topo
        self.rows = list(rows)
        self.seed = seed
        self.backend = resolve_backend(backend)
        self.probe_dim = probe_dim
        self._inboxes: Dict[int, object] = {}
        self._outbox = None
        self._handles: Dict[int, object] = {}
        self._alive: Set[int] = set()
        self._started = False

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._started:
            raise RuntimeError("pool already started")
        self._started = True
        if self.backend == "process":
            import multiprocessing as mp

            # spawn, not fork: the master has live jax/XLA threads and a
            # forked child would inherit their locks; spawned children
            # import only this numpy-only module
            ctx = mp.get_context("spawn")
            self._outbox = ctx.Queue()
            make_inbox = ctx.Queue

            def launch(flat, row, inbox):
                p = ctx.Process(
                    target=_worker_main,
                    args=(flat, row, self.seed, inbox, self._outbox),
                    daemon=True,
                )
                p.start()
                return p
        else:
            self._outbox = queue_lib.Queue()
            make_inbox = queue_lib.Queue

            def launch(flat, row, inbox):
                t = threading.Thread(
                    target=_worker_main,
                    args=(flat, row, self.seed, inbox, self._outbox),
                    daemon=True,
                )
                t.start()
                return t
        for flat in range(self.topo.total_workers):
            inbox = make_inbox()
            self._inboxes[flat] = inbox
            self._handles[flat] = launch(flat, self.rows[flat], inbox)
            self._alive.add(flat)

    @property
    def alive(self) -> Set[int]:
        return set(self._alive)

    # ------------------------------------------------------------------
    def dispatch(self, flat: int, work: WorkItem) -> bool:
        """Send one round's work item; False if the worker is dead."""
        if flat not in self._alive:
            return False
        self._inboxes[flat].put(("work", work))
        return True

    def collect(self, step: int, expected: Set[int], *,
                timeout_s: float = 60.0) -> Dict[int, Result]:
        """Drain results for ``step`` from every expected live worker.

        REAL time only bounds the wait for processes to finish their
        (fast) numpy work — all *scheduling* semantics ride the virtual
        ``sent_ms`` stamps, so a slow CI runner changes nothing.  Stale
        results from earlier rounds (a worker killed mid-collect last
        round) are dropped.
        """
        results: Dict[int, Result] = {}
        pending = {f for f in expected if f in self._alive}
        deadline = time.monotonic() + timeout_s
        while pending:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                msg = self._outbox.get(timeout=min(remaining, 0.5))
            except queue_lib.Empty:
                continue
            except Exception:  # mp.Queue raises its own Empty
                continue
            if msg[0] != "result":
                continue
            res: Result = msg[1]
            if res.step != step:
                continue
            results[res.flat] = res
            pending.discard(res.flat)
        return results

    def inject_message(self, msg) -> None:
        """Test hook: push a raw message into the master's inbox."""
        self._outbox.put(msg)

    # ------------------------------------------------------------------
    def kill(self, flat: int) -> bool:
        """Terminate a worker permanently; True if it was alive."""
        if flat not in self._alive:
            return False
        self._alive.discard(flat)
        h = self._handles[flat]
        if self.backend == "process":
            h.terminate()
        else:
            self._inboxes[flat].put(("stop",))
        return True

    def close(self) -> None:
        for flat in list(self._alive):
            self._alive.discard(flat)
            if self.backend == "process":
                self._handles[flat].terminate()
            else:
                self._inboxes[flat].put(("stop",))
        for flat, h in self._handles.items():
            h.join(timeout=5.0)

    def __enter__(self) -> "WorkerPool":
        if not self._started:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def rows_from_params(params) -> List[ModelRow]:
    """Per-worker :class:`ModelRow` slices of a ``ClusterParams``."""
    topo = params.topo
    rows = []
    for i in range(topo.n):
        for j in range(topo.m[i]):
            f = topo.flat_index(i, j)
            rows.append(ModelRow(
                c=float(params.c[f]), gamma=float(params.gamma[f]),
                tau_w=float(params.tau_w[f]), p_w=float(params.p_w[f]),
                tau_e=float(params.tau_e[i]), p_e=float(params.p_e[i]),
            ))
    return rows
