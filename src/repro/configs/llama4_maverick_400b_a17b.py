"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) ff=8192,
V=202048, 128 experts top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

The flagship scale config: 128-expert top-1 routing with one shared
expert, MoE interleaved every other layer (dense layers ff=16384), as in
Maverick — that interleaving is what lands total params at ~400B with
~17B active.  Experts shard 128/16 = 8-way over the model axis (EP).
Early-fusion multimodality enters through the same embedding stream
(frontend stubs, as with the VLM entry).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    rope_theta=500_000.0,
    block_pattern=("global", "global"),
    moe_pattern=(False, True),
    d_ff_dense=16_384,
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    capacity_factor=1.25,
    param_dtype="bfloat16",  # 400B: bf16 master + adafactor fits HBM
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab=256,
    block_pattern=("global", "global"),
    moe_pattern=(False, True),
    d_ff_dense=128,
    n_experts=8,
    top_k=1,
    n_shared_experts=1,
    capacity_factor=2.0,
    attn_chunk=32,
)
