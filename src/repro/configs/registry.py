"""--arch registry: maps architecture ids to (full, smoke) ModelConfigs.

Smoke configs keep the family structure (pattern, MoE, GQA ratios …) at
toy width/depth so one train/forward step runs on CPU in seconds.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = (
    "llama3-8b",
    "granite-8b",
    "starcoder2-3b",
    "gemma3-27b",
    "qwen2-vl-2b",
    "recurrentgemma-2b",
    "whisper-medium",
    "mamba2-370m",
    "granite-moe-3b-a800m",
    "llama4-maverick-400b-a17b",
)

_MODULES = {a: a.replace("-", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch in ("paper-mnist-lr", "paper-cifar-cnn"):
        raise ValueError(
            f"{arch} is a classic model — use repro.models.classic"
        )
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: which (arch × shape) cells run.

    * long_500k only for sub-quadratic archs (DESIGN.md §4),
    * decode shapes skipped for encoder-only archs (none assigned).
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "dense-attention arch: 512k decode is out of scope"
    return True, ""


def all_cells():
    """All (arch, shape) cells with applicability flags."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
