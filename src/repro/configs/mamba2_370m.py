"""mamba2-370m [ssm]: 48L d=1024 attn-free, d_state=128, V=50280.

SSD (state-space duality) [arXiv:2405.21060; unverified].
Sub-quadratic ⇒ runs long_500k.  d_inner = 2·d, headdim 64 ⇒ 32 heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,  # unused (attention-free)
    d_ff=0,
    vocab=50_280,
    block_pattern=("ssm",),
    d_state=128,
    expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    subquadratic=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab=256,
    block_pattern=("ssm",),
    d_state=16,
    expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    subquadratic=True,
    tie_embeddings=True,
)
