"""Config system: model architecture, input shapes, training, cluster.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``repro.configs.registry`` maps ``--arch`` ids to them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (pure data; consumed by repro.models)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 ⇒ d_model // n_heads

    # layer-kind pattern, repeated cyclically over n_layers.
    #   "global"    full causal attention
    #   "local"     sliding-window causal attention (window)
    #   "recurrent" RG-LRU block
    #   "ssm"       Mamba-2 SSD block
    block_pattern: Tuple[str, ...] = ("global",)
    window: int = 0

    rope_theta: float = 10_000.0
    mlp: str = "swiglu"  # swiglu | gelu
    norm: str = "rms"  # rms | layer
    logit_softcap: float = 0.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    # per-pattern-position MoE flags (empty ⇒ all layers MoE when n_experts>0)
    moe_pattern: Tuple[bool, ...] = ()
    d_ff_dense: int = 0  # FFN width of non-MoE layers (0 ⇒ d_ff)

    # SSM (mamba2)
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # RG-LRU (recurrentgemma)
    lru_width: int = 0

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_len: int = 1500

    # VLM (qwen2-vl)
    mrope_sections: Tuple[int, ...] = ()

    # numerics / structure
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_chunk: int = 1024  # kv-chunked attention when seq > this
    q_chunk: int = 2048  # additionally q-chunk when seq ≥ 8·attn_chunk
    # flash-style custom-VJP attention (recompute in backward) — §Perf
    flash: bool = False
    # remat policy: "full" recomputes the whole group (baseline);
    # "save_block_outputs" checkpoints the post-all-reduce block outputs
    # so the backward recompute skips the TP activation all-reduces
    # (≈ −1/3 of the collective term at +2·(B,S,d)/layer memory) — §Perf
    remat_policy: str = "full"

    # Whether a 512k dense decode is feasible (sub-quadratic archs only).
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def layer_kind(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def moe_at(self, layer_idx: int) -> bool:
        if not self.is_moe:
            return False
        if not self.moe_pattern:
            return True
        return self.moe_pattern[layer_idx % len(self.block_pattern)]

    # --------------------------------------------------------------
    # parameter counting (for MODEL_FLOPS = 6·N·D roofline term)
    # --------------------------------------------------------------
    def param_counts(self) -> Tuple[int, int]:
        """(total_params, active_params) — active differs for MoE."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        H, Kv, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += d * V  # head
        per_layer_total = 0
        per_layer_active = 0
        n_attn_like = 0
        for l in range(self.n_layers):
            kind = self.layer_kind(l)
            if kind in ("global", "local"):
                attn = d * H * Dh + 2 * d * Kv * Dh + H * Dh * d
                if self.moe_at(l):
                    mlp_t = self.n_experts * 3 * d * ff + d * self.n_experts
                    mlp_a = self.top_k * 3 * d * ff + d * self.n_experts
                    mlp_t += self.n_shared_experts * 3 * d * ff
                    mlp_a += self.n_shared_experts * 3 * d * ff
                elif self.mlp == "swiglu":
                    ffd = self.d_ff_dense or ff
                    mlp_t = mlp_a = 3 * d * ffd
                else:
                    ffd = self.d_ff_dense or ff
                    mlp_t = mlp_a = 2 * d * ffd
                per_layer_total += attn + mlp_t
                per_layer_active += attn + mlp_a
                n_attn_like += 1
            elif kind == "recurrent":
                r = self.lru_width or d
                blk = 2 * d * r + 2 * r * r + r * d + 4 * r
                mlp = 3 * d * ff if self.mlp == "swiglu" else 2 * d * ff
                per_layer_total += blk + mlp
                per_layer_active += blk + mlp
            elif kind == "ssm":
                di = self.expand * d
                nh = di // self.ssm_head_dim
                in_p = d * (2 * di + 2 * self.d_state + nh)
                blk = in_p + self.d_conv * (di + 2 * self.d_state) + di * d
                per_layer_total += blk
                per_layer_active += blk
        total += per_layer_total
        active = V * d + (0 if self.tie_embeddings else d * V)
        active += per_layer_active
        if self.is_encdec:
            # encoder layers: full attention + mlp (gelu), plus decoder
            # cross-attn already folded into n_layers pattern by config.
            enc = self.n_enc_layers * (
                4 * d * H * Dh + 2 * d * ff
            )
            total += enc
            active += enc
        return total, active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Training-loop hyperparameters + HGC wiring."""

    optimizer: str = "adamw"  # sgd | momentum | adamw | adafactor
    lr: float = 3e-4
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    microbatch: int = 0  # 0 ⇒ no accumulation; else per-step microbatch
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
    # HGC (aggregation scheme at the data-parallel layer)
    scheme: str = "uncoded"  # any of core.schemes.SCHEME_NAMES
    s_e: int = 1
    s_w: int = 1
    K: int = 0  # 0 ⇒ auto (compatible_K)
    # fault tolerance
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    # distributed perf knobs (see EXPERIMENTS.md §Perf)
    remat_policy: str = "layer"  # layer | none | dots
    # aggregation execution mode (launch.train --dist):
    #   off        — single-host reference loop, λ rides the batch weights
    #   coded      — shard_map two-stage coded psum on a (pod, data[, model]) mesh
    #   coded_int8 — same, with the int8 + error-feedback cross-pod hop
    #   coded_q    — same, codec chosen by grad_compression (int8|int4|fp8)
    dist_mode: str = "off"
    # edge→master hop codec: none | int8 | int4 (packed nibbles) | fp8
    # (e4m3); all three share the EF-residual contract, so checkpoints
    # restore across codecs (dist/compression.py)
    grad_compression: str = "none"
    grad_compression_block: int = 64  # quantization block on that hop
    fsdp: bool = True  # shard params over the data axis as well
    # sequence parallelism (Megatron SP) inside the dist-TP shard_map:
    # row-parallel out-projections reduce-scatter over seq, the
    # norm/residual work between the TP collective pairs runs on the
    # local 1/tp seq block, column-parallel in-projections re-gather.
    # Config-level default; the train CLI's --seq-shard/--no-seq-shard
    # flag (CodedSession ``seq_shard=``) overrides it.  Needs tp > 1
    # and seq_len % tp == 0 (sharding.validate_seq_shard).
    seq_shard_activations: bool = False
    # pipeline parallelism over the leading "stage" mesh axis: the
    # stacked layer groups shard stage-wise (each stage owns a
    # contiguous block of n_groups // pp_stages groups) and the dist
    # train step runs a microbatched pipeline schedule with ppermute
    # activation handoffs.  Needs n_groups % pp_stages == 0
    # (sharding.validate_pp).  1 ⇒ off (no "stage" mesh axis at all).
    pp_stages: int = 1
    # pipeline microbatch COUNT per step (distinct from ``microbatch``,
    # the accumulation SIZE of the single-host path): the per-group
    # coded batch splits into this many microbatches flowing through
    # the stage pipeline.  0 ⇒ pp_stages (minimum that fills the
    # pipeline); must divide the per-group batch rows.
    microbatches: int = 0
