"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) ff=8960 V=151936.

M-RoPE (temporal/height/width sections) + dynamic resolution
[arXiv:2409.12191; hf].  The vision frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings and
3-D position ids; the backbone here is the full text decoder with
M-RoPE sections (16, 24, 24) over head_dim 128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151_936,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    mrope_sections=(2, 3, 3),
    tie_embeddings=True,
    attn_chunk=32,
)
