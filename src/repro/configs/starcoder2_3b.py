"""starcoder2-3b [dense]: 30L d=3072 24H (GQA kv=2) ff=12288 V=49152.

GQA + RoPE [arXiv:2402.19173; hf].  StarCoder2-3B uses a plain GELU MLP
and layernorm (GPT-lineage), reflected here.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    rope_theta=100_000.0,
    mlp="gelu",
    norm="layer",
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=256,
    mlp="gelu",
    norm="layer",
    attn_chunk=32,
)
