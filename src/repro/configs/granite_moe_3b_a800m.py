"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) ff=512/expert,
V=49155, 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

The assignment line says both "MoE 40e" and "32 experts"; we follow the
shape-spec field (40 experts, top-8) — discrepancy noted in DESIGN.md.
Experts are small (ff=512) ⇒ expert FFN dim is tensor-parallel while the
expert axis stays replicated (40 ∤ 16).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49_155,
    n_experts=40,
    top_k=8,
    capacity_factor=1.25,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab=256,
    n_experts=8,
    top_k=2,
    capacity_factor=2.0,
    tie_embeddings=True,
    attn_chunk=32,
)
