"""whisper-medium [audio]: enc-dec, 24+24L d=1024 16H ff=4096 V=51865.

Encoder-decoder with conv frontend STUB [arXiv:2212.04356; unverified]:
``input_specs()`` provides precomputed 1500-frame embeddings (the output
of whisper's conv subsampling of 30 s of mel spectrogram).  The "24L"
assignment line is read as 24 encoder + 24 decoder layers (matching the
real whisper-medium).  Whisper is MHA (kv = heads) with GELU MLPs and
layernorm; learned positions are stood in by RoPE (noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,  # decoder layers
    n_enc_layers=24,
    enc_len=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51_865,
    mlp="gelu",
    norm="layer",
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    enc_len=30,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    mlp="gelu",
    norm="layer",
    attn_chunk=32,
)
