"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) ff=7680.

RG-LRU + local attention, 1 attention : 2 recurrent pattern, 2048-token
window [arXiv:2402.19427; hf].  Sub-quadratic ⇒ runs long_500k.
26 = 8 scanned (rec, rec, local) groups + 2 trailing recurrent layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    block_pattern=("recurrent", "recurrent", "local"),
    window=2048,
    lru_width=2560,
    rope_theta=10_000.0,
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    n_layers=5,  # one (rec,rec,local) group + 2 rest recurrents
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=256,
    block_pattern=("recurrent", "recurrent", "local"),
    window=16,
    lru_width=64,
    tie_embeddings=True,
    subquadratic=True,
    attn_chunk=32,
)
