"""gemma3-27b [dense]: 62L d=5376 32H (GQA kv=16) ff=21504 V=262144.

5:1 local:global attention pattern, 1024-token sliding window on local
layers, 128k context [hf:google/gemma-3-1b-pt; unverified].
62 = 10 scanned (5·local + 1·global) groups + 2 trailing local layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262_144,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-27b-smoke",
    family="dense",
    n_layers=8,  # one full 6-group + 2 rest layers — exercises both paths
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    window=16,
    tie_embeddings=True,
    attn_chunk=32,
)
