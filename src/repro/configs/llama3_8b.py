"""llama3-8b [dense]: 32L d=4096 32H (GQA kv=8) ff=14336 V=128256.

GQA + 128k vocab + RoPE θ=500k [arXiv:2407.21783; unverified].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=256,
    rope_theta=500_000.0,
    attn_chunk=32,
)
