"""Pure-jnp oracles for the Pallas kernels (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def coded_combine_ref(coeff: jnp.ndarray, grads: jnp.ndarray) -> jnp.ndarray:
    """out[r, f] = Σ_k coeff[r, k] · grads[k, f].

    The HGC hot-spot: encoding (worker messages from part-gradients,
    eq. 22) and decoding (weighted recombination, eqs. 25/27) are both
    this skinny matmul over a huge flattened-gradient F axis.
    """
    return jnp.einsum(
        "rk,kf->rf", coeff.astype(jnp.float32), grads.astype(jnp.float32)
    ).astype(grads.dtype)


def coded_combine_q_ref(
    coeff: jnp.ndarray,  # (R, K) f32
    grads_q: jnp.ndarray,  # (K, F) int8
    scales: jnp.ndarray,  # (K, F // block) f32 per-block scales
    block: int,
) -> jnp.ndarray:
    """Fused int8-dequant coded combine (gradient-compression path)."""
    K, F = grads_q.shape
    nb = F // block
    g = grads_q.reshape(K, nb, block).astype(jnp.float32)
    g = g * scales[:, :, None]
    out = jnp.einsum("rk,knb->rnb", coeff.astype(jnp.float32), g)
    return out.reshape(coeff.shape[0], F)


def coded_combine_q4_ref(
    coeff: jnp.ndarray,  # (R, K) f32
    grads_q: jnp.ndarray,  # (K, F // 2) int8, packed int4 pairs
    scales: jnp.ndarray,  # (K, F // block) f32 per-block scales
    block: int,
) -> jnp.ndarray:
    """Packed-int4 variant: unpack nibbles, then the q combine."""
    K, F2 = grads_q.shape
    p = grads_q.astype(jnp.int32) & 0xFF
    lo = ((p & 0xF) ^ 8) - 8
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    g = jnp.stack([lo, hi], axis=-1).reshape(K, F2 * 2).astype(jnp.int8)
    return coded_combine_q_ref(coeff, g, scales, block)


def coded_combine_f8_ref(
    coeff: jnp.ndarray,  # (R, K) f32
    grads_q: jnp.ndarray,  # (K, F) float8_e4m3fn
    scales: jnp.ndarray,  # (K, F // block) f32 per-block scales
    block: int,
) -> jnp.ndarray:
    """fp8-e4m3 variant of the fused dequant combine."""
    K, F = grads_q.shape
    nb = F // block
    g = grads_q.astype(jnp.float32).reshape(K, nb, block)
    g = g * scales[:, :, None]
    out = jnp.einsum("rk,knb->rnb", coeff.astype(jnp.float32), g)
    return out.reshape(coeff.shape[0], F)


def decode_attention_ref(
    q: jnp.ndarray,        # (B, 1, H, Dh) — one new token per sequence
    k_cache: jnp.ndarray,  # (B, C, Kv, Dh) ring-buffer keys
    v_cache: jnp.ndarray,  # (B, C, Kv, Dh) ring-buffer values
    q_pos,                 # scalar int — absolute position of the token
    k_pos: jnp.ndarray,    # (C,) int — absolute position per slot, <0 empty
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """GQA decode attention over a ring-buffer cache (pure-jnp oracle).

    Mirrors :func:`repro.models.attention.decode_attention` — kept here
    (kernels may not import models) as the allclose target for the
    Pallas kernel: H = Kv·G query heads share Kv cache heads; a slot is
    attendable iff it holds a real position ≤ q_pos inside the window.
    """
    B, _, H, Dh = q.shape
    Kv = k_cache.shape[2]
    G = H // Kv
    qf = q.astype(jnp.float32).reshape(B, Kv, G, Dh)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, kf) / jnp.sqrt(
        jnp.float32(Dh))
    if softcap and softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    ok = (k_pos >= 0) & (k_pos <= q_pos)
    if window and window > 0:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, vf)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)
