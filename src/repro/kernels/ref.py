"""Pure-jnp oracles for the Pallas kernels (allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def coded_combine_ref(coeff: jnp.ndarray, grads: jnp.ndarray) -> jnp.ndarray:
    """out[r, f] = Σ_k coeff[r, k] · grads[k, f].

    The HGC hot-spot: encoding (worker messages from part-gradients,
    eq. 22) and decoding (weighted recombination, eqs. 25/27) are both
    this skinny matmul over a huge flattened-gradient F axis.
    """
    return jnp.einsum(
        "rk,kf->rf", coeff.astype(jnp.float32), grads.astype(jnp.float32)
    ).astype(grads.dtype)


def coded_combine_q_ref(
    coeff: jnp.ndarray,  # (R, K) f32
    grads_q: jnp.ndarray,  # (K, F) int8
    scales: jnp.ndarray,  # (K, F // block) f32 per-block scales
    block: int,
) -> jnp.ndarray:
    """Fused int8-dequant coded combine (gradient-compression path)."""
    K, F = grads_q.shape
    nb = F // block
    g = grads_q.reshape(K, nb, block).astype(jnp.float32)
    g = g * scales[:, :, None]
    out = jnp.einsum("rk,knb->rnb", coeff.astype(jnp.float32), g)
    return out.reshape(coeff.shape[0], F)
