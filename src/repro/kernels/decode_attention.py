"""Pallas TPU kernel: fused single-token decode attention over the
ring-buffer KV cache.

The serve hot loop (`api/serving.make_decode_fn`) runs one token per
step: q is (B, 1, H, Dh) against a (B, C, Kv, Dh) ring cache whose
write pointer is ``pos % C``.  The XLA path materializes the full
(B, Kv, G, C) score tensor in HBM every token; this kernel keeps the
scores and the online-softmax state (m, l, acc) in VMEM for the whole
cache sweep — per token, HBM sees only q, the cache, and the (B, 1, H,
Dh) output.

Grid: (B·Kv,) — one program per (sequence, kv head); the G query heads
of a GQA group share that program's cache block, so the cache is read
ONCE per group instead of once per query head.  The kv sweep is a
fori_loop over C/bk blocks, mirroring `flash_attention._flash_fwd_kernel`.

Slot validity is derived *inside* the kernel from the ring write
pointer: a slot s of a cache filled to length L = q_pos+1 with
effective window W holds absolute position

    k_pos(s) = s + W · ⌊(L − 1 − s) / W⌋        (W = window or C)

which is negative for never-written slots (mask), and the usual causal
/ sliding-window predicates apply on top.  This reproduces
`models.attention.ring_slot_positions` without materializing the (C,)
position vector in HBM.  q_pos rides in as a (1, 1) i32 operand so the
token index stays a runtime value — the serve loop never recompiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BK_DECODE = 128
G_PAD = 8  # f32 sublane — query-group rows pad up to this


def _decode_attn_kernel(qpos_ref, q_ref, k_ref, v_ref, o_ref, *,
                        bk: int, cache_size: int, window: int,
                        softcap: float, scale: float):
    # refs: qpos (1, 1) i32; q (1, Gp, Dh); k/v (1, Cp, Dh); o (1, Gp, Dh)
    qp = qpos_ref[0, 0]
    q = q_ref[...][0].astype(jnp.float32) * scale  # (Gp, Dh)
    Gp, Dh = q.shape
    Cp = k_ref.shape[1]
    weff = window if window > 0 else cache_size

    def body(ik, carry):
        m, l, acc = carry
        k_blk = k_ref[0, pl.dslice(ik * bk, bk), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.dslice(ik * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (Gp, bk)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        slot = ik * bk + jax.lax.broadcasted_iota(
            jnp.int32, (1, bk), 1)
        # ring write pointer → absolute position held by each slot
        k_pos = slot + weff * ((qp + 1 - 1 - slot) // weff)
        ok = (slot < cache_size) & (k_pos >= 0) & (k_pos <= qp)
        if window > 0:
            ok &= qp - k_pos < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((Gp, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Gp, 1), jnp.float32)
    a0 = jnp.zeros((Gp, Dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, Cp // bk, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30))[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "bk", "interpret"),
)
def decode_attention_fwd(
    q: jnp.ndarray,        # (B, 1, H, Dh)
    k_cache: jnp.ndarray,  # (B, C, Kv, Dh)
    v_cache: jnp.ndarray,  # (B, C, Kv, Dh)
    q_pos,                 # scalar i32, runtime operand (no recompile)
    window: int = 0,
    softcap: float = 0.0,
    bk: int = BK_DECODE,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused GQA ring-buffer decode attention; out (B, 1, H, Dh).

    Drop-in for `models.attention.decode_attention` on the self-attn
    ring path: callers pass the raw write-pointer state (q_pos = pos,
    the cfg window, the cache) and the slot-position vector is derived
    in-kernel.  Matches the XLA path to f32 accumulation error
    (tests/test_decode_attention.py).
    """
    B, one, H, Dh = q.shape
    assert one == 1, q.shape
    C, Kv = k_cache.shape[1], k_cache.shape[2]
    G = H // Kv
    assert G * Kv == H, (H, Kv)

    # fold (B, Kv) into the grid; G query heads share one cache block
    qf = q.reshape(B, Kv, G, Dh).reshape(B * Kv, G, Dh)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Kv, C, Dh)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Kv, C, Dh)

    Gp = -(-G // G_PAD) * G_PAD
    Cp = -(-C // bk) * bk
    qf = jnp.pad(qf, ((0, 0), (0, Gp - G), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, Cp - C), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, Cp - C), (0, 0)))
    qpos = jnp.asarray(q_pos, jnp.int32).reshape(1, 1)

    kernel = functools.partial(
        _decode_attn_kernel, bk=bk, cache_size=C, window=window,
        softcap=softcap, scale=1.0 / (Dh ** 0.5),
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * Kv,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b: (0, 0)),
            pl.BlockSpec((1, Gp, Dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Cp, Dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Cp, Dh), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Gp, Dh), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Kv, Gp, Dh), q.dtype),
        interpret=interpret,
    )(qpos, qf, kf, vf)
    return out[:, :G, :].reshape(B, 1, H, Dh)
