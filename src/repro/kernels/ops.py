"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU
the same pallas_call compiles to Mosaic.  ``encode_tree`` /
``decode_tree`` wire the kernel into the HGC pytree world.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.coded_combine import coded_combine, coded_combine_q

PyTree = Any


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def combine(coeff, grads, use_pallas: bool = True):
    """out = coeff @ grads with the kernel (interpret on CPU)."""
    if not use_pallas:
        return ref.coded_combine_ref(coeff, grads)
    return coded_combine(coeff, grads, interpret=not _on_tpu())


def combine_q(coeff, grads_q, scales, block: int = 128,
              use_pallas: bool = True):
    if not use_pallas:
        return ref.coded_combine_q_ref(coeff, grads_q, scales, block)
    return coded_combine_q(
        coeff, grads_q, scales, block=block, interpret=not _on_tpu()
    )


def flatten_tree(tree: PyTree) -> jnp.ndarray:
    return jnp.concatenate([jnp.ravel(x) for x in jax.tree.leaves(tree)])


def unflatten_like(vec: jnp.ndarray, tree: PyTree) -> PyTree:
    leaves = jax.tree.leaves(tree)
    treedef = jax.tree.structure(tree)
    out = []
    off = 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(vec[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def encode_messages(code, g_parts: jnp.ndarray) -> jnp.ndarray:
    """All workers' messages G_ij at once: (Σm_i, F) = E @ g_parts.

    ``E`` is the collapsed encoding matrix (worker coeffs ⊙ layer-1
    rows) — one kernel launch instead of Σm_i separate combines.
    """
    E = jnp.asarray(code.encoding_matrix_flat(), jnp.float32)
    return combine(E, g_parts)


def decode_gradient(code, messages: jnp.ndarray, fast_edges,
                    fast_workers) -> jnp.ndarray:
    """Decoded full gradient from worker messages via λ weights."""
    lam = jnp.asarray(
        code.collapsed_weights(fast_edges, fast_workers), jnp.float32
    )
    return combine(lam[None, :], messages)[0]
