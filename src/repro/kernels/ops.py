"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU
the same pallas_call compiles to Mosaic.  ``encode_tree`` /
``decode_tree`` wire the kernel into the HGC pytree world.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.coded_combine import (
    coded_combine,
    coded_combine_f8,
    coded_combine_q,
    coded_combine_q4,
)
from repro.kernels.decode_attention import decode_attention_fwd

PyTree = Any


def on_tpu() -> bool:
    """True iff the default jax backend is a real TPU.

    The one place the ``use_pallas`` defaults come from (kernels run
    compiled on TPU, interpret-mode elsewhere).  ``dist._compat``
    re-exports this for the layers above kernels.
    """
    return jax.default_backend() == "tpu"


_on_tpu = on_tpu  # old private name, kept for stragglers


def combine(coeff, grads, use_pallas: bool = True):
    """out = coeff @ grads with the kernel (interpret on CPU)."""
    if not use_pallas:
        return ref.coded_combine_ref(coeff, grads)
    return coded_combine(coeff, grads, interpret=not on_tpu())


def combine_q(coeff, grads_q, scales, block: int = 128,
              use_pallas: bool = True):
    if not use_pallas:
        return ref.coded_combine_q_ref(coeff, grads_q, scales, block)
    return coded_combine_q(
        coeff, grads_q, scales, block=block, interpret=not on_tpu()
    )


def combine_q4(coeff, grads_q, scales, block: int = 128,
               use_pallas: bool = True):
    """Packed-int4 fused dequant combine (grads_q is (K, F//2) bytes)."""
    if not use_pallas:
        return ref.coded_combine_q4_ref(coeff, grads_q, scales, block)
    return coded_combine_q4(
        coeff, grads_q, scales, block=block, interpret=not on_tpu()
    )


def combine_f8(coeff, grads_q, scales, block: int = 128,
               use_pallas: bool = True):
    """fp8-e4m3 fused dequant combine."""
    if not use_pallas:
        return ref.coded_combine_f8_ref(coeff, grads_q, scales, block)
    return coded_combine_f8(
        coeff, grads_q, scales, block=block, interpret=not on_tpu()
    )


#: compression mode → fused dequant-combine wrapper
COMBINE_BY_MODE = {
    "int8": combine_q,
    "int4": combine_q4,
    "fp8": combine_f8,
}


def combine_compressed(mode: str, coeff, grads_q, scales,
                       block: int = 128, use_pallas: bool = True):
    """Dispatch the fused combine matching a compression codec."""
    try:
        fn = COMBINE_BY_MODE[mode]
    except KeyError:
        raise ValueError(
            f"no fused combine for compression mode {mode!r}"
        ) from None
    return fn(coeff, grads_q, scales, block=block, use_pallas=use_pallas)


def decode_attention(q, k_cache, v_cache, q_pos, window: int = 0,
                     softcap: float = 0.0, use_pallas: bool = True):
    """Ring-buffer GQA decode attention; out (B, 1, H, Dh).

    With ``use_pallas=False`` the jnp oracle runs (slot positions
    materialized via the same ring formula the kernel derives in VMEM).
    """
    if not use_pallas:
        C = k_cache.shape[1]
        weff = window if window > 0 else C
        s = jnp.arange(C)
        k_pos = s + weff * ((q_pos - s) // weff)
        return ref.decode_attention_ref(
            q, k_cache, v_cache, q_pos, k_pos,
            window=window, softcap=softcap,
        )
    return decode_attention_fwd(
        q, k_cache, v_cache, q_pos, window=window, softcap=softcap,
        interpret=not on_tpu(),
    )


def flatten_tree(tree: PyTree) -> jnp.ndarray:
    return jnp.concatenate([jnp.ravel(x) for x in jax.tree.leaves(tree)])


def unflatten_like(vec: jnp.ndarray, tree: PyTree) -> PyTree:
    leaves = jax.tree.leaves(tree)
    treedef = jax.tree.structure(tree)
    out = []
    off = 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(vec[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def encode_messages(code, g_parts: jnp.ndarray) -> jnp.ndarray:
    """All workers' messages G_ij at once: (Σm_i, F) = E @ g_parts.

    ``E`` is the collapsed encoding matrix (worker coeffs ⊙ layer-1
    rows) — one kernel launch instead of Σm_i separate combines.
    """
    E = jnp.asarray(code.encoding_matrix_flat(), jnp.float32)
    return combine(E, g_parts)


def decode_gradient(code, messages: jnp.ndarray, fast_edges,
                    fast_workers) -> jnp.ndarray:
    """Decoded full gradient from worker messages via λ weights."""
    lam = jnp.asarray(
        code.collapsed_weights(fast_edges, fast_workers), jnp.float32
    )
    return combine(lam[None, :], messages)[0]
