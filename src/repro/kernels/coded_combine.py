"""Pallas TPU kernel: coded gradient combine  out = C @ G.

C (R, K) is the coding matrix (R worker rows or one decode row), G
(K, F) the stacked per-part flattened gradients — F is the model size
(10⁶–10¹¹), K ≤ a few hundred.  This is the encode (eq. 22) / decode
(eqs. 25/27) hot-spot of the paper.

TPU adaptation (DESIGN.md §3): a GPU implementation would stripe K over
thread blocks; on TPU we keep the skinny K axis resident in VMEM and
tile the huge F axis so each grid step is one MXU-shaped (Rb×K)·(K×Fb)
matmul:

  grid  = (R/Rb, F/Fb)
  C blk = (Rb, K)     — revisited per F tile (tiny, stays in VMEM)
  G blk = (K, Fb)     — streamed from HBM
  out   = (Rb, Fb)

Fb = 512 keeps the working set (K·Fb + Rb·K + Rb·Fb) ≪ 16 MB VMEM for
K ≤ 2048 and is lane-aligned (128); Rb = 8 matches the f32 sublane.
The kernel is validated in interpret mode on CPU (tests/test_kernels.py)
and compiled for TPU via the same pallas_call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

R_BLOCK = 8
F_BLOCK = 512


def _combine_kernel(c_ref, g_ref, o_ref):
    # c_ref: (Rb, K), g_ref: (K, Fb), o_ref: (Rb, Fb)
    c = c_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(
        c, g, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def coded_combine(
    coeff: jnp.ndarray,  # (R, K)
    grads: jnp.ndarray,  # (K, F)
    interpret: bool = True,
) -> jnp.ndarray:
    """out (R, F) = coeff @ grads, tiled for VMEM.  Pads R and F."""
    R, K = coeff.shape
    K2, F = grads.shape
    assert K == K2, (coeff.shape, grads.shape)
    Rp = -(-R // R_BLOCK) * R_BLOCK
    Fp = -(-F // F_BLOCK) * F_BLOCK
    cp = jnp.pad(coeff, ((0, Rp - R), (0, 0)))
    gp = jnp.pad(grads, ((0, 0), (0, Fp - F)))
    out = pl.pallas_call(
        _combine_kernel,
        grid=(Rp // R_BLOCK, Fp // F_BLOCK),
        in_specs=[
            pl.BlockSpec((R_BLOCK, K), lambda r, f: (r, 0)),
            pl.BlockSpec((K, F_BLOCK), lambda r, f: (0, f)),
        ],
        out_specs=pl.BlockSpec((R_BLOCK, F_BLOCK), lambda r, f: (r, f)),
        out_shape=jax.ShapeDtypeStruct((Rp, Fp), grads.dtype),
        interpret=interpret,
    )(cp, gp)
    return out[:R, :F]


def _combine_q_kernel(c_ref, g_ref, s_ref, o_ref, *, block: int):
    # c: (Rb, K), g: (K, Fb) int8, s: (K, Fb/block), o: (Rb, Fb)
    c = c_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    s = s_ref[...]  # (K, nb)
    K, Fb = g.shape
    nb = Fb // block
    g = (g.reshape(K, nb, block) * s[:, :, None]).reshape(K, Fb)
    o_ref[...] = jnp.dot(
        c, g, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "interpret")
)
def coded_combine_q(
    coeff: jnp.ndarray,  # (R, K) f32
    grads_q: jnp.ndarray,  # (K, F) int8
    scales: jnp.ndarray,  # (K, F // block) f32
    block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused int8-dequant coded combine (compression path).

    The de-quantization happens in VMEM right before the MXU matmul —
    HBM only ever sees int8 gradients (4× traffic cut vs f32).
    F must be a multiple of ``block``; F_BLOCK must too (128 | 512 ✓).
    """
    R, K = coeff.shape
    K2, F = grads_q.shape
    assert K == K2 and F % block == 0
    Rp = -(-R // R_BLOCK) * R_BLOCK
    Fp = -(-F // F_BLOCK) * F_BLOCK
    nb_blk = F_BLOCK // block
    cp = jnp.pad(coeff, ((0, Rp - R), (0, 0)))
    gp = jnp.pad(grads_q, ((0, 0), (0, Fp - F)))
    sp = jnp.pad(scales, ((0, 0), (0, (Fp - F) // block)))
    out = pl.pallas_call(
        functools.partial(_combine_q_kernel, block=block),
        grid=(Rp // R_BLOCK, Fp // F_BLOCK),
        in_specs=[
            pl.BlockSpec((R_BLOCK, K), lambda r, f: (r, 0)),
            pl.BlockSpec((K, F_BLOCK), lambda r, f: (0, f)),
            pl.BlockSpec((K, nb_blk), lambda r, f: (0, f)),
        ],
        out_specs=pl.BlockSpec((R_BLOCK, F_BLOCK), lambda r, f: (r, f)),
        out_shape=jax.ShapeDtypeStruct((Rp, Fp), jnp.float32),
        interpret=interpret,
    )(cp, gp, sp)
    return out[:R, :F]


def _combine_q4_kernel(c_ref, g_ref, s_ref, o_ref, *, block: int):
    # c: (Rb, K), g: (K, Fb/2) packed int4 pairs, s: (K, Fb/block),
    # o: (Rb, Fb).  Nibbles unpack in VMEM — HBM traffic is 0.5 B/value.
    c = c_ref[...].astype(jnp.float32)
    p = g_ref[...].astype(jnp.int32) & 0xFF  # unsigned byte view
    lo = ((p & 0xF) ^ 8) - 8                 # even value: low nibble
    hi = (((p >> 4) & 0xF) ^ 8) - 8          # odd value: high nibble
    K, Fb2 = p.shape
    g = jnp.stack([lo, hi], axis=-1).reshape(K, Fb2 * 2)
    g = g.astype(jnp.float32)
    s = s_ref[...]  # (K, nb)
    Fb = Fb2 * 2
    nb = Fb // block
    g = (g.reshape(K, nb, block) * s[:, :, None]).reshape(K, Fb)
    o_ref[...] = jnp.dot(
        c, g, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "interpret")
)
def coded_combine_q4(
    coeff: jnp.ndarray,  # (R, K) f32
    grads_q: jnp.ndarray,  # (K, F // 2) int8, two int4 values per byte
    scales: jnp.ndarray,  # (K, F // block) f32
    block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused packed-int4 dequant coded combine.

    ``grads_q`` carries two nibbles per byte in
    :func:`repro.dist.compression.pack_int4` layout (value 2i in the
    low nibble of byte i) — 8× less HBM/wire traffic than f32.  The
    sign-extend + interleave + scale all happen in VMEM.
    """
    R, K = coeff.shape
    K2, F2 = grads_q.shape
    F = F2 * 2
    assert K == K2 and F % block == 0 and block % 2 == 0
    Rp = -(-R // R_BLOCK) * R_BLOCK
    Fp = -(-F // F_BLOCK) * F_BLOCK
    nb_blk = F_BLOCK // block
    cp = jnp.pad(coeff, ((0, Rp - R), (0, 0)))
    gp = jnp.pad(grads_q, ((0, 0), (0, (Fp - F) // 2)))
    sp = jnp.pad(scales, ((0, 0), (0, (Fp - F) // block)))
    out = pl.pallas_call(
        functools.partial(_combine_q4_kernel, block=block),
        grid=(Rp // R_BLOCK, Fp // F_BLOCK),
        in_specs=[
            pl.BlockSpec((R_BLOCK, K), lambda r, f: (r, 0)),
            pl.BlockSpec((K, F_BLOCK // 2), lambda r, f: (0, f)),
            pl.BlockSpec((K, nb_blk), lambda r, f: (0, f)),
        ],
        out_specs=pl.BlockSpec((R_BLOCK, F_BLOCK), lambda r, f: (r, f)),
        out_shape=jax.ShapeDtypeStruct((Rp, Fp), jnp.float32),
        interpret=interpret,
    )(cp, gp, sp)
    return out[:R, :F]


def _combine_f8_kernel(c_ref, g_ref, s_ref, o_ref, *, block: int):
    # c: (Rb, K), g: (K, Fb) fp8-e4m3, s: (K, Fb/block), o: (Rb, Fb)
    c = c_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    s = s_ref[...]
    K, Fb = g.shape
    nb = Fb // block
    g = (g.reshape(K, nb, block) * s[:, :, None]).reshape(K, Fb)
    o_ref[...] = jnp.dot(
        c, g, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "interpret")
)
def coded_combine_f8(
    coeff: jnp.ndarray,  # (R, K) f32
    grads_q: jnp.ndarray,  # (K, F) float8_e4m3fn
    scales: jnp.ndarray,  # (K, F // block) f32
    block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused fp8-e4m3 dequant coded combine.

    Identical tiling to :func:`coded_combine_q`, but the payload is a
    blockwise-scaled float8 — same 4× traffic cut as int8 with relative
    (rather than fixed-grid) per-value precision.  The f32 upcast
    happens in VMEM right before the MXU matmul.
    """
    R, K = coeff.shape
    K2, F = grads_q.shape
    assert K == K2 and F % block == 0
    Rp = -(-R // R_BLOCK) * R_BLOCK
    Fp = -(-F // F_BLOCK) * F_BLOCK
    nb_blk = F_BLOCK // block
    cp = jnp.pad(coeff, ((0, Rp - R), (0, 0)))
    gp = jnp.pad(grads_q, ((0, 0), (0, Fp - F)))
    sp = jnp.pad(scales, ((0, 0), (0, (Fp - F) // block)))
    out = pl.pallas_call(
        functools.partial(_combine_f8_kernel, block=block),
        grid=(Rp // R_BLOCK, Fp // F_BLOCK),
        in_specs=[
            pl.BlockSpec((R_BLOCK, K), lambda r, f: (r, 0)),
            pl.BlockSpec((K, F_BLOCK), lambda r, f: (0, f)),
            pl.BlockSpec((K, nb_blk), lambda r, f: (0, f)),
        ],
        out_specs=pl.BlockSpec((R_BLOCK, F_BLOCK), lambda r, f: (r, f)),
        out_shape=jax.ShapeDtypeStruct((Rp, Fp), jnp.float32),
        interpret=interpret,
    )(cp, gp, sp)
    return out[:R, :F]
