"""Pallas TPU flash-attention (forward) kernel.

Motivation (EXPERIMENTS.md §Perf, gemma3-27b × prefill_32k): ≥50% of
the prefill memory-roofline term is attention score blocks crossing
XLA fusion boundaries.  This kernel keeps the (Bq × Bk) score tile and
the online-softmax state (m, l, acc) in VMEM for the whole kv sweep —
score traffic to HBM drops to ZERO; HBM sees only q, k, v and out.

Grid: (batch·kv_heads·q_groups, S/Bq); the kv loop is a fori_loop
inside the kernel with VMEM accumulators.  Block shapes are MXU-aligned
(Bq=512, Bk=512, Dh multiple of 128 — all assigned configs comply).

Layout: q (BH, S, Dh), k/v (BH, T, Dh) — callers fold (batch, kv_head,
group) into BH (GQA: repeat kv per group or fold groups into BH with a
shared kv index — see ops wrapper).  Causal + sliding-window masks are
iota-derived inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BQ = 512
BK = 512


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int,
                      causal: bool, window: int, scale: float):
    # block refs: q (1, BQ, Dh); k/v (1, T, Dh); o (1, BQ, Dh)
    iq = pl.program_id(1)
    T = k_ref.shape[1]
    Bq = q_ref.shape[1]
    q = q_ref[...][0].astype(jnp.float32) * scale  # (BQ, Dh)
    q_pos = iq * Bq + jax.lax.broadcasted_iota(
        jnp.int32, (Bq, 1), 0)

    def body(ik, carry):
        m, l, acc = carry
        k_blk = k_ref[0, pl.dslice(ik * bk, bk), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.dslice(ik * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (BQ, bk)
        k_pos = ik * bk + jax.lax.broadcasted_iota(
            jnp.int32, (1, bk), 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    Dh = q_ref.shape[2]
    m0 = jnp.full((Bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Bq, 1), jnp.float32)
    a0 = jnp.zeros((Bq, Dh), jnp.float32)
    n_k = T // bk
    m, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30))[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"),
)
def flash_attention_fwd(
    q: jnp.ndarray,  # (BH, S, Dh)
    k: jnp.ndarray,  # (BH, T, Dh)
    v: jnp.ndarray,  # (BH, T, Dh)
    causal: bool = True,
    window: int = 0,
    bq: int = BQ,
    bk: int = BK,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, S, Dh = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    scale = 1.0 / (Dh ** 0.5)
    kernel = functools.partial(
        _flash_fwd_kernel, bk=bk, causal=causal, window=window,
        scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, T, Dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, T, Dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v)


def flash_attention_gqa(
    q: jnp.ndarray,  # (B, S, H, Dh)
    k: jnp.ndarray,  # (B, T, Kv, Dh)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """GQA wrapper: folds (B, Kv, G) into the kernel's BH axis."""
    B, S, H, Dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qf = q.reshape(B, S, Kv, G, Dh).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(B * Kv * G, S, Dh)
    kf = jnp.repeat(
        k.transpose(0, 2, 1, 3), G, axis=1
    ).reshape(B * Kv * G, -1, Dh)
    vf = jnp.repeat(
        v.transpose(0, 2, 1, 3), G, axis=1
    ).reshape(B * Kv * G, -1, Dh)
    out = flash_attention_fwd(
        qf, kf, vf, causal=causal, window=window, interpret=interpret
    )
    out = out.reshape(B, Kv, G, S, Dh).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, S, H, Dh)
