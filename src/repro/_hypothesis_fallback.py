"""Offline stand-in for the ``hypothesis`` property-testing API.

The container has no network and no ``hypothesis`` wheel; six test
modules would otherwise fail at *collection*.  This module implements
the tiny subset they use — ``given`` / ``settings`` / ``strategies``
(integers, sampled_from, booleans, lists, data) — by running each
property on a fixed, seeded set of representative examples instead of
adaptive search.  No shrinking, no database; determinism over power.

Usage (at the top of a test module)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                     # offline fallback
        from repro._hypothesis_fallback import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib
from typing import Any, Callable, List, Optional, Sequence

# Cap per-test examples: real hypothesis asks for 12–60; the fallback's
# fixed draws add no coverage past a dozen and CPU time is the budget.
MAX_FALLBACK_EXAMPLES = 10
_DEFAULT_EXAMPLES = 8
_ATTR = "_fallback_max_examples"


class SearchStrategy:
    """Base strategy: a deterministic sampler over a value domain."""

    def example(self, rng: random.Random) -> Any:
        raise NotImplementedError

    # hypothesis API niceties some suites use
    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base: SearchStrategy, fn):
        self.base, self.fn = base, fn

    def example(self, rng):
        return self.fn(self.base.example(rng))


class _Filtered(SearchStrategy):
    def __init__(self, base: SearchStrategy, pred):
        self.base, self.pred = base, pred

    def example(self, rng):
        for _ in range(1000):
            v = self.base.example(rng)
            if self.pred(v):
                return v
        raise ValueError("filter predicate rejected 1000 examples")


class _Integers(SearchStrategy):
    def __init__(self, min_value: int = 0, max_value: Optional[int] = None):
        self.lo = int(min_value)
        self.hi = int(max_value) if max_value is not None else self.lo + 100

    def example(self, rng):
        # bias toward the boundaries — where real hypothesis finds bugs
        r = rng.random()
        if r < 0.25:
            return self.lo
        if r < 0.4:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from needs a non-empty sequence")

    def example(self, rng):
        return rng.choice(self.elements)


class _Booleans(SearchStrategy):
    def example(self, rng):
        return rng.random() < 0.5


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size: int = 0,
                 max_size: Optional[int] = None, unique: bool = False):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 5
        self.unique = unique

    def example(self, rng):
        size = rng.randint(self.min_size, max(self.min_size, self.max_size))
        out: List[Any] = []
        tries = 0
        while len(out) < size and tries < 200:
            v = self.elements.example(rng)
            tries += 1
            if self.unique and v in out:
                continue
            out.append(v)
        return out


class _DataStrategy(SearchStrategy):
    """Marker for ``st.data()``; materialized per-example as _DataObject."""

    def example(self, rng):
        return _DataObject(rng)


class _DataObject:
    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label: str = "") -> Any:
        return strategy.example(self._rng)


class strategies:  # noqa: N801 — mirrors the hypothesis module name
    @staticmethod
    def integers(min_value: int = 0, max_value: Optional[int] = None):
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def lists(elements, *, min_size: int = 0, max_size: Optional[int] = None,
              unique: bool = False):
        return _Lists(elements, min_size, max_size, unique)

    @staticmethod
    def data():
        return _DataStrategy()


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Decorator recording the example budget on the wrapped test."""

    def deco(fn):
        setattr(fn, _ATTR, min(int(max_examples), MAX_FALLBACK_EXAMPLES))
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test on a fixed seeded batch of drawn examples.

    Draws are deterministic (seeded by the test name), so failures
    reproduce; each example re-seeds so one bad draw doesn't mask the
    rest of the batch.
    """

    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        pos_map = dict(zip(names, arg_strategies))
        pos_map.update(kw_strategies)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(
                wrapper, _ATTR,
                getattr(fn, _ATTR, _DEFAULT_EXAMPLES),
            )
            base_seed = zlib.crc32(fn.__qualname__.encode()) & 0x7FFFFFFF
            for i in range(n):
                rng = random.Random(base_seed + i)
                drawn = {k: s.example(rng) for k, s in pos_map.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:  # annotate the failing example
                    shown = {
                        k: v for k, v in drawn.items()
                        if not isinstance(v, _DataObject)
                    }
                    raise AssertionError(
                        f"falsifying example (fallback #{i}): {shown}"
                    ) from e
            return None

        # preserve a settings() applied above the given() decorator
        if hasattr(fn, _ATTR):
            setattr(wrapper, _ATTR, getattr(fn, _ATTR))
        # hide the drawn parameters from pytest's fixture resolution
        # (hypothesis does the same): only pass-through params remain.
        remaining = [p for name, p in sig.parameters.items()
                     if name not in pos_map]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return deco
