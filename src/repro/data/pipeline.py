"""Data pipeline: deterministic synthetic datasets, the paper's non-IID
partitioner, K-part assignment-aware loaders, and token streams.

The container is offline, so MNIST/CIFAR are stood in by deterministic
synthetic datasets with identical shapes and a class structure that
makes the paper's non-IID levels meaningful (per-class Gaussian modes —
a linear/CNN model genuinely has to separate classes, and dropping a
part biases the gradient exactly as in the paper).  EXPERIMENTS.md
validates *relative* scheme behaviour against the paper's claims.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.assignment import Assignment
from repro.core.topology import Topology


# ----------------------------------------------------------------------
# synthetic image-classification datasets (MNIST-like / CIFAR-like)
# ----------------------------------------------------------------------
def synthetic_classification(
    n: int,
    shape: Tuple[int, ...],
    n_classes: int = 10,
    seed: int = 0,
    class_sep: float = 2.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Gaussian class-mode dataset: x = μ_class + ε, deterministic."""
    rng = np.random.default_rng(seed)
    dim = int(np.prod(shape))
    mus = rng.normal(size=(n_classes, dim)) * class_sep / np.sqrt(dim)
    y = rng.integers(0, n_classes, size=n)
    x = mus[y] + rng.normal(size=(n, dim)) * 0.5
    return x.reshape((n,) + shape).astype(np.float32), y.astype(np.int64)


def mnist_like(n: int = 10_000, seed: int = 0):
    """784-feature 10-class stand-in (paper's MNIST-LR experiment)."""
    return synthetic_classification(n, (784,), 10, seed)


def cifar_like(n: int = 10_000, seed: int = 1):
    """32×32×3 10-class stand-in (paper's CIFAR-CNN experiment)."""
    return synthetic_classification(n, (32, 32, 3), 10, seed)


# ----------------------------------------------------------------------
# the paper's K-part splits and non-IID levels (§V-A)
# ----------------------------------------------------------------------
def split_K_parts(
    x: np.ndarray,
    y: np.ndarray,
    K: int,
    non_iid_level: int = 1,
    n_classes: int = 10,
    seed: int = 0,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """K disjoint sub-datasets at the paper's non-IID levels:

      Level 1 — samples drawn from all classes,
      Level 2 — each part sees ≤ 5 classes,
      Level 3 — each part sees ≤ 2 classes.
    """
    rng = np.random.default_rng(seed)
    max_types = {1: n_classes, 2: 5, 3: 2}[non_iid_level]
    by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    ptr = [0] * n_classes
    per_part = len(y) // K
    parts = []
    for k in range(K):
        classes = rng.choice(n_classes, size=max_types, replace=False)
        idxs: List[int] = []
        # round-robin over the allowed classes until the part is full
        ci = 0
        guard = 0
        while len(idxs) < per_part and guard < 10 * per_part:
            c = classes[ci % len(classes)]
            if ptr[c] < len(by_class[c]):
                idxs.append(by_class[c][ptr[c]])
                ptr[c] += 1
            ci += 1
            guard += 1
        if len(idxs) < per_part:  # refill from any class
            pool = np.concatenate(
                [bc[p:] for bc, p in zip(by_class, ptr) if p < len(bc)]
            )
            idxs.extend(pool[: per_part - len(idxs)].tolist())
        idxs = np.asarray(idxs[:per_part])
        parts.append((x[idxs], y[idxs]))
    return parts


def worker_part_loader(
    parts: Sequence[Tuple[np.ndarray, np.ndarray]],
    assignment: Assignment,
) -> Dict[Tuple[int, int], List[int]]:
    """Worker (i,j) → the global part ids it must process (eq. 19)."""
    out = {}
    for i in range(assignment.topo.n):
        for j in range(assignment.topo.m[i]):
            out[(i, j)] = list(assignment.worker_parts(i, j))
    return out


# ----------------------------------------------------------------------
# token streams for the LM architectures
# ----------------------------------------------------------------------
@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic LM token stream with resumable state.

    The iterator state (step counter) is part of the training
    checkpoint, so restart resumes the exact data order — required for
    the fault-tolerance story.
    """

    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    step: int = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.step])
        )
        # structured stream: a noisy periodic source so a real LM can
        # actually reduce loss on it
        base = rng.integers(0, self.vocab, size=(self.batch, 1))
        drift = np.arange(self.seq_len)[None, :]
        tokens = (base + drift + rng.integers(0, 3, size=(
            self.batch, self.seq_len))) % self.vocab
        self.step += 1
        targets = np.roll(tokens, -1, axis=1)
        return {
            "tokens": tokens.astype(np.int32),
            "targets": targets.astype(np.int32),
            "weights": np.ones((self.batch, self.seq_len), np.float32),
        }

    def state_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: Dict):
        self.seed, self.step = int(d["seed"]), int(d["step"])


def coded_batch(
    stream_parts: Sequence[Dict[str, np.ndarray]],
    coeffs: Sequence[float],
) -> Dict[str, np.ndarray]:
    """Stack a worker's assigned parts into one batch whose example
    weights carry the HGC coding coefficients (DESIGN.md §3).

    The gradient of the weighted loss on this batch IS the worker's
    encoded message G_ij.
    """
    tokens = np.concatenate([p["tokens"] for p in stream_parts], 0)
    targets = np.concatenate([p["targets"] for p in stream_parts], 0)
    weights = np.concatenate(
        [p["weights"] * c for p, c in zip(stream_parts, coeffs)], 0
    )
    return {"tokens": tokens, "targets": targets, "weights": weights}
