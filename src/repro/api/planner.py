"""Pluggable planning strategies: cluster model → deployed ``Plan``.

The seam the ROADMAP's scenario family plugs into: a ``Planner`` turns
a :class:`~repro.core.runtime_model.ClusterParams` into a
:class:`~repro.dist.elastic.Plan` (tolerance + built HGC code + λ
provider).  Three built-ins:

  * ``jncss``   — the paper's Algorithm 2 grid search (adaptive: the
    session re-invokes it on detector-updated params at replan time),
  * ``fixed``   — a pinned (s_e, s_w) tolerance,
  * ``uniform`` — uncoded baseline, tolerance (0, 0).

Two further strategies implement the families the module docstring of
:mod:`repro.core.grouping` / :mod:`repro.core.comm_tradeoff` describe:

  * ``grouped``     — heterogeneity-aware per-edge worker tolerances
    (Wang et al. 1901.09339 flavor): never slower than JNCSS in the
    model, strictly faster on intra-edge-heterogeneous clusters,
  * ``comm_budget`` — communication-budgeted tolerance selection
    (Gholami et al. 2502.18251 flavor): the cheapest exact code whose
    per-iteration message counts fit the given master/edge budgets.

Any other strategy drops in the same way: implement ``plan()`` and hand
the instance to ``CodedSession(planner=...)`` — no driver fork required.
See ``docs/planners.md`` for the selection guide.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

from repro.core import tradeoff
from repro.core.comm_tradeoff import solve_comm_budget
from repro.core.grouping import (
    GroupedHGCCode,
    GroupTolerance,
    compatible_K_grouped,
    plan_grouped,
    price_grouped,
)
from repro.core.hgc import HGCCode
from repro.core.runtime_model import ClusterParams
from repro.core.topology import Tolerance, Topology
from repro.dist.elastic import Plan, price_tolerance, replan


@runtime_checkable
class Planner(Protocol):
    """Strategy protocol: price tolerances, build the deployed code."""

    def initial_K(self, topo: Topology) -> int:
        """Target part count before construction-compatibility bumping."""
        ...

    def plan(self, params: ClusterParams, K: int, *, seed: int = 0,
             reuse: Optional[HGCCode] = None) -> Plan:
        """Plan a tolerance for ``params`` and build/reuse its code.

        ``reuse`` is the currently deployed code: when the strategy
        lands on the same (tolerance, K, topology) it MUST be returned
        as-is (identity, not equality) so the caller's part streams and
        compiled step stay valid with zero churn.
        """
        ...


@dataclasses.dataclass(frozen=True)
class JNCSSPlanner:
    """The paper's Algorithm 2: expected-iteration-time grid search.

    ``s_e_hint``/``s_w_hint`` only size the initial K request (the
    search itself picks the tolerance).
    """

    s_e_hint: int = 1
    s_w_hint: int = 1
    construction: str = "random"

    def initial_K(self, topo: Topology) -> int:
        return tradeoff.compatible_K(
            topo, Tolerance(self.s_e_hint, self.s_w_hint),
            at_least=topo.total_workers,
        )

    def plan(self, params: ClusterParams, K: int, *, seed: int = 0,
             reuse: Optional[HGCCode] = None) -> Plan:
        return replan(params, K, seed=seed,
                      construction=self.construction, reuse=reuse)


@dataclasses.dataclass(frozen=True)
class FixedPlanner:
    """A pinned tolerance: deploy (s_e, s_w) regardless of the cluster.

    The tolerance is clamped to what the topology can carry (at least
    one surviving edge / worker per edge) — a fixed-tolerance run that
    shrinks past a permanent failure keeps planning instead of dying.
    """

    s_e: int = 1
    s_w: int = 1
    construction: str = "random"

    @property
    def tol(self) -> Tolerance:
        return Tolerance(self.s_e, self.s_w)

    def _clamped(self, topo: Topology) -> Tolerance:
        return Tolerance(
            max(min(self.s_e, topo.n - 1), 0),
            max(min(self.s_w, min(topo.m) - 1), 0),
        )

    def initial_K(self, topo: Topology) -> int:
        return tradeoff.compatible_K(
            topo, self._clamped(topo), at_least=topo.total_workers
        )

    def plan(self, params: ClusterParams, K: int, *, seed: int = 0,
             reuse: Optional[HGCCode] = None) -> Plan:
        tol = self._clamped(params.topo)
        K_c = tradeoff.compatible_K(params.topo, tol, at_least=K)
        if (reuse is not None and reuse.tol == tol and reuse.K == K_c
                and reuse.topo == params.topo):
            code = reuse
        else:
            code = HGCCode.build(params.topo, tol, K=K_c, seed=seed,
                                 construction=self.construction)
        return Plan(
            code=code, tol=tol, K=K_c,
            expected_iteration_ms=price_tolerance(params, tol, code.load),
            jncss=None,
        )


@dataclasses.dataclass(frozen=True)
class UniformPlanner(FixedPlanner):
    """Uncoded baseline: no redundancy, wait for everyone."""

    s_e: int = 0
    s_w: int = 0


@dataclasses.dataclass(frozen=True)
class GroupedPlanner:
    """Heterogeneity-aware grouping: per-edge worker tolerances.

    Runs :func:`repro.core.grouping.plan_grouped` — JNCSS's outer s_e
    grid with a decoupled per-edge argmin over each edge's own s_w^i —
    and deploys a :class:`~repro.core.grouping.GroupedHGCCode`.  The
    uniform vector is always a candidate, so the model-expected time is
    never worse than JNCSS's; it is strictly better when worker speeds
    differ *within* edges.

    Caveat: non-uniform per-edge loads are incompatible with the
    ``--dist`` modes' even batch sharding — the session rejects such
    plans there (single-host mode and the simulator take them fine).
    """

    s_e_hint: int = 1
    s_w_hint: int = 1
    construction: str = "random"  # read by session resume; random only

    def initial_K(self, topo: Topology) -> int:
        return tradeoff.compatible_K(
            topo, Tolerance(self.s_e_hint, self.s_w_hint),
            at_least=topo.total_workers,
        )

    def plan(self, params: ClusterParams, K: int, *, seed: int = 0,
             reuse: Optional[HGCCode] = None) -> Plan:
        res = plan_grouped(params, K)
        gtol = GroupTolerance(res.s_e, res.s_w_vec)
        K_c = compatible_K_grouped(params.topo, gtol, at_least=K)
        if (reuse is not None and reuse.tol == gtol and reuse.K == K_c
                and reuse.topo == params.topo):
            code = reuse
        else:
            code = GroupedHGCCode.build(
                params.topo, gtol, K=K_c, seed=seed
            )
        return Plan(
            code=code, tol=gtol, K=K_c,
            expected_iteration_ms=price_grouped(params, gtol, code.loads),
            jncss=None,
        )


@dataclasses.dataclass(frozen=True)
class CommBudgetPlanner:
    """Communication-budgeted planning: cheapest code that fits the
    per-iteration message budgets.

    Budgets resolve per topology: ``max_master_msgs`` /
    ``max_edge_msgs`` pin them absolutely, otherwise ``master_shave`` /
    ``edge_shave`` subtract from the uncoded counts (``n`` master
    messages, ``max_i m_i`` at the busiest edge).  Tightening a budget
    forces tolerance — and with it per-worker compute — up: the
    communication↔computation trade-off.
    """

    max_master_msgs: Optional[int] = None
    max_edge_msgs: Optional[int] = None
    master_shave: int = 1
    edge_shave: int = 0
    construction: str = "random"

    def _budgets(self, topo: Topology):
        master = self.max_master_msgs
        if master is None:
            master = max(1, topo.n - self.master_shave)
        edge = self.max_edge_msgs
        if edge is None:
            edge = max(1, max(topo.m) - self.edge_shave)
        return master, edge

    def initial_K(self, topo: Topology) -> int:
        # size the K request at the loosest-tolerance corner; plan()
        # re-bumps for the tolerance the budget actually forces
        return tradeoff.compatible_K(
            topo, Tolerance(0, 0), at_least=topo.total_workers
        )

    def plan(self, params: ClusterParams, K: int, *, seed: int = 0,
             reuse: Optional[HGCCode] = None) -> Plan:
        master, edge = self._budgets(params.topo)
        point = solve_comm_budget(
            params, K, max_master_msgs=master, max_edge_msgs=edge
        )
        tol = point.tol
        K_c = tradeoff.compatible_K(params.topo, tol, at_least=K)
        if (reuse is not None and reuse.tol == tol and reuse.K == K_c
                and reuse.topo == params.topo):
            code = reuse
        else:
            code = HGCCode.build(params.topo, tol, K=K_c, seed=seed,
                                 construction=self.construction)
        return Plan(
            code=code, tol=tol, K=K_c,
            expected_iteration_ms=price_tolerance(params, tol, code.load),
            jncss=None,
        )


def get_planner(spec, s_e: int = 1, s_w: int = 1) -> Planner:
    """Resolve a planner: an instance passes through; a string picks a
    built-in strategy (``"jncss"`` | ``"fixed"`` | ``"uniform"`` |
    ``"grouped"`` | ``"comm_budget"``)."""
    if isinstance(spec, str):
        if spec == "jncss":
            return JNCSSPlanner(s_e_hint=s_e, s_w_hint=s_w)
        if spec == "fixed":
            return FixedPlanner(s_e, s_w)
        if spec == "uniform":
            return UniformPlanner()
        if spec == "grouped":
            return GroupedPlanner(s_e_hint=s_e, s_w_hint=s_w)
        if spec == "comm_budget":
            return CommBudgetPlanner(master_shave=s_e, edge_shave=s_w)
        raise ValueError(
            f"unknown planner {spec!r} (expected jncss | fixed | uniform "
            f"| grouped | comm_budget or a Planner instance)"
        )
    if not isinstance(spec, Planner):
        raise TypeError(f"not a Planner: {spec!r}")
    return spec


def planner_for_scheme(scheme: str, s_e: int = 1, s_w: int = 1) -> Planner:
    """The train CLI's ``--scheme`` names → planner strategies."""
    return get_planner(
        {
            "hgc_jncss": "jncss",
            "hgc": "fixed",
            "uncoded": "uniform",
            "hgc_grouped": "grouped",
            "hgc_comm": "comm_budget",
        }.get(scheme, scheme),
        s_e, s_w,
    )
