"""Pluggable planning strategies: cluster model → deployed ``Plan``.

The seam the ROADMAP's scenario family plugs into: a ``Planner`` turns
a :class:`~repro.core.runtime_model.ClusterParams` into a
:class:`~repro.dist.elastic.Plan` (tolerance + built HGC code + λ
provider).  Three built-ins:

  * ``jncss``   — the paper's Algorithm 2 grid search (adaptive: the
    session re-invokes it on detector-updated params at replan time),
  * ``fixed``   — a pinned (s_e, s_w) tolerance,
  * ``uniform`` — uncoded baseline, tolerance (0, 0).

Heterogeneity-aware planning (Wang et al. 2019) or the communication–
computation trade-off family (Gholami et al. 2025) drop in as further
strategies: implement ``plan()`` and hand the instance to
``CodedSession(planner=...)`` — no driver fork required.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

from repro.core import tradeoff
from repro.core.hgc import HGCCode
from repro.core.runtime_model import ClusterParams
from repro.core.topology import Tolerance, Topology
from repro.dist.elastic import Plan, price_tolerance, replan


@runtime_checkable
class Planner(Protocol):
    """Strategy protocol: price tolerances, build the deployed code."""

    def initial_K(self, topo: Topology) -> int:
        """Target part count before construction-compatibility bumping."""
        ...

    def plan(self, params: ClusterParams, K: int, *, seed: int = 0,
             reuse: Optional[HGCCode] = None) -> Plan:
        """Plan a tolerance for ``params`` and build/reuse its code.

        ``reuse`` is the currently deployed code: when the strategy
        lands on the same (tolerance, K, topology) it MUST be returned
        as-is (identity, not equality) so the caller's part streams and
        compiled step stay valid with zero churn.
        """
        ...


@dataclasses.dataclass(frozen=True)
class JNCSSPlanner:
    """The paper's Algorithm 2: expected-iteration-time grid search.

    ``s_e_hint``/``s_w_hint`` only size the initial K request (the
    search itself picks the tolerance).
    """

    s_e_hint: int = 1
    s_w_hint: int = 1
    construction: str = "random"

    def initial_K(self, topo: Topology) -> int:
        return tradeoff.compatible_K(
            topo, Tolerance(self.s_e_hint, self.s_w_hint),
            at_least=topo.total_workers,
        )

    def plan(self, params: ClusterParams, K: int, *, seed: int = 0,
             reuse: Optional[HGCCode] = None) -> Plan:
        return replan(params, K, seed=seed,
                      construction=self.construction, reuse=reuse)


@dataclasses.dataclass(frozen=True)
class FixedPlanner:
    """A pinned tolerance: deploy (s_e, s_w) regardless of the cluster.

    The tolerance is clamped to what the topology can carry (at least
    one surviving edge / worker per edge) — a fixed-tolerance run that
    shrinks past a permanent failure keeps planning instead of dying.
    """

    s_e: int = 1
    s_w: int = 1
    construction: str = "random"

    @property
    def tol(self) -> Tolerance:
        return Tolerance(self.s_e, self.s_w)

    def _clamped(self, topo: Topology) -> Tolerance:
        return Tolerance(
            max(min(self.s_e, topo.n - 1), 0),
            max(min(self.s_w, min(topo.m) - 1), 0),
        )

    def initial_K(self, topo: Topology) -> int:
        return tradeoff.compatible_K(
            topo, self._clamped(topo), at_least=topo.total_workers
        )

    def plan(self, params: ClusterParams, K: int, *, seed: int = 0,
             reuse: Optional[HGCCode] = None) -> Plan:
        tol = self._clamped(params.topo)
        K_c = tradeoff.compatible_K(params.topo, tol, at_least=K)
        if (reuse is not None and reuse.tol == tol and reuse.K == K_c
                and reuse.topo == params.topo):
            code = reuse
        else:
            code = HGCCode.build(params.topo, tol, K=K_c, seed=seed,
                                 construction=self.construction)
        return Plan(
            code=code, tol=tol, K=K_c,
            expected_iteration_ms=price_tolerance(params, tol, code.load),
            jncss=None,
        )


@dataclasses.dataclass(frozen=True)
class UniformPlanner(FixedPlanner):
    """Uncoded baseline: no redundancy, wait for everyone."""

    s_e: int = 0
    s_w: int = 0


def get_planner(spec, s_e: int = 1, s_w: int = 1) -> Planner:
    """Resolve a planner: an instance passes through; a string picks a
    built-in strategy (``"jncss"`` | ``"fixed"`` | ``"uniform"``)."""
    if isinstance(spec, str):
        if spec == "jncss":
            return JNCSSPlanner(s_e_hint=s_e, s_w_hint=s_w)
        if spec == "fixed":
            return FixedPlanner(s_e, s_w)
        if spec == "uniform":
            return UniformPlanner()
        raise ValueError(
            f"unknown planner {spec!r} (expected jncss | fixed | uniform "
            f"or a Planner instance)"
        )
    if not isinstance(spec, Planner):
        raise TypeError(f"not a Planner: {spec!r}")
    return spec


def planner_for_scheme(scheme: str, s_e: int = 1, s_w: int = 1) -> Planner:
    """The train CLI's ``--scheme`` names → planner strategies."""
    return get_planner(
        {"hgc_jncss": "jncss", "hgc": "fixed", "uncoded": "uniform"}.get(
            scheme, scheme
        ),
        s_e, s_w,
    )
