"""Serving building blocks: compiled prefill → decode handoff + sampling.

Two prefill lowerings, both single-dispatch jittable functions (the
session shards them onto a tensor-parallel mesh by pinning in/out
shardings — the function bodies never change):

  * **bulk** (default): one ``tf.prefill`` forward over the whole
    prompt, re-laid into the decode ring buffers by
    ``tf.prefill_to_decode_cache`` — S× fewer dispatches and a
    matmul-shaped lowering instead of S sequential decode steps,
  * **exact** (``exact=True``, and the automatic fallback for archs
    whose recurrent/cross-attention states only exist on the decode
    path): the prompt fed through ``decode_step`` one token at a time —
    inside one ``lax.scan``, so even the debug path compiles once.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


def make_prefill_fn(
    cfg: ModelConfig,
    max_len: int,
    *,
    exact: bool = False,
    dtype: str = "float32",
) -> Callable:
    """→ ``prefill(params, tokens[, enc_frames]) → (last_logits, cache)``.

    ``cache`` is in ``decode_step`` layout either way; ``last_logits``
    is ``(B, V)`` — the logits the first generated token samples from.
    """
    use_bulk = tf.bulk_prefill_supported(cfg) and not exact

    def bulk(params, tokens, enc_frames=None):
        logits, pcache = tf.prefill(params, cfg, tokens, last_only=True)
        cache = tf.prefill_to_decode_cache(cfg, pcache, max_len,
                                           dtype=dtype)
        return logits[:, -1], cache

    def exact_loop(params, tokens, enc_frames=None):
        B, S = tokens.shape
        cache = tf.init_cache(cfg, B, max_len, dtype=dtype)
        if cfg.is_encdec:
            cache = tf.fill_cross_cache(params, cfg, enc_frames, cache)

        def body(cache, tok):
            logits, cache = tf.decode_step(params, cfg, tok[:, None],
                                           cache)
            return cache, logits

        cache, logits = lax.scan(body, cache, jnp.moveaxis(tokens, 1, 0))
        return logits[-1], cache

    return bulk if use_bulk else exact_loop


def make_decode_fn(
    cfg: ModelConfig, use_pallas: Optional[bool] = None
) -> Callable:
    """→ ``decode(params, token, cache) → (logits, cache)``.

    ``use_pallas`` gates the fused ring-buffer decode-attention kernel
    in the serve hot loop (None ⇒ auto: compiled kernel on TPU, XLA
    attention elsewhere; True forces the kernel — interpret mode
    off-TPU, the parity path CI exercises).  The switch is resolved
    ONCE here so every jitted decode dispatch takes the same path.
    """
    if use_pallas is None:
        from repro.kernels.ops import on_tpu

        use_pallas = on_tpu()

    def decode(params, token, cache):
        return tf.decode_step(params, cfg, token, cache,
                              use_pallas=use_pallas)

    return decode


def generate_tokens(
    params,
    cfg: ModelConfig,
    prompt,
    gen_len: int,
    *,
    prefill_fn: Callable,
    decode_fn: Callable,
    enc_frames=None,
    greedy: bool = True,
    seed: int = 0,
) -> np.ndarray:
    """The generation loop over prebuilt (possibly sharded) step fns."""
    if cfg.is_encdec:
        logits, cache = prefill_fn(params, prompt, enc_frames)
    else:
        logits, cache = prefill_fn(params, prompt)
    rng = jax.random.PRNGKey(seed)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(gen_len):
        out.append(np.asarray(tok))
        logits, cache = decode_fn(params, tok, cache)
        if greedy:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits)[:, None].astype(
                jnp.int32)
    return np.concatenate(out, axis=1)


def prefill_into_cache(
    params,
    cfg: ModelConfig,
    tokens,
    max_len: int,
    enc_frames=None,
    *,
    exact: bool = False,
) -> Tuple[jnp.ndarray, object]:
    """Single-host convenience: jit + run one prefill → (logits, cache)."""
    fn = jax.jit(make_prefill_fn(cfg, max_len, exact=exact))
    if cfg.is_encdec:
        return fn(params, tokens, enc_frames)
    return fn(params, tokens)


def generate(
    params,
    cfg: ModelConfig,
    prompt,
    gen_len: int,
    max_len: Optional[int] = None,
    enc_frames=None,
    greedy: bool = True,
    seed: int = 0,
    exact_handoff: bool = False,
) -> np.ndarray:
    """Single-host generation (the ``CodedSession.generate`` tp=1 path)."""
    prompt = jnp.asarray(prompt, jnp.int32)
    max_len = max_len or prompt.shape[1] + gen_len + 1
    prefill_fn = jax.jit(
        make_prefill_fn(cfg, max_len, exact=exact_handoff)
    )
    decode_fn = jax.jit(make_decode_fn(cfg))
    return generate_tokens(
        params, cfg, prompt, gen_len, prefill_fn=prefill_fn,
        decode_fn=decode_fn, enc_frames=enc_frames, greedy=greedy,
        seed=seed,
    )
