"""`CodedSession` — the deployed coded system as one object.

The paper's system is a hierarchical cluster with a deployed code, a
runtime model and an elastic replanning loop; this class owns all of it:
the device mesh and sharded training state, the compiled train / eval /
prefill / decode steps, the per-part data streams, the straggler
simulation + detector feedback, JNCSS replanning, permanent-failure
shrinking, and the checkpoint round trip (bit-for-bit kill/resume).

The aggregation policies of the train CLI map to ``mode``:

  * ``"off"``        — single-host reference: λ rides the per-example
    batch weights and the jit gradient reduction decodes implicitly,
  * ``"coded"``      — (pod, data[, model]) mesh, two-stage coded
    shard_map decode with λ as a runtime operand (zero recompiles
    across straggler drops and replans),
  * ``"coded_int8"`` — same, with the blockwise-int8 + error-feedback
    edge→master hop (per-pod EF residuals ride the training state),
  * ``"coded_q"``    — same hop with the codec ``grad_compression``
    selects (int8 default, int4 packed nibbles, or fp8-e4m3) — all
    three share the f32 EF-residual contract, so checkpoints,
    kill/resume, and replans behave identically across codecs.

Quickstart::

    from repro.api import CodedCluster, CodedSession
    from repro.configs.registry import get_smoke_config

    cluster = CodedCluster.hetero(n_edges=2, n_workers=4)
    session = CodedSession(cluster, get_smoke_config("llama3-8b"),
                           planner="jncss", total_steps=20)
    session.fit()
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import serving
from repro.api.cluster import CodedCluster, sample_straggler_pattern
from repro.api.planner import Planner, get_planner
from repro.checkpoint.store import CheckpointStore, config_hash
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.hgc import HGCCode
from repro.core.topology import Tolerance
from repro.dist.elastic import Plan, price_tolerance
from repro.data.pipeline import TokenStream
from repro.models import transformer as tf
from repro.optim import make_optimizer

PyTree = Any


class ReplanError(RuntimeError):
    """A replan/shrink produced a plan the deployed session cannot run.

    Raised INSTEAD of adopting the offending plan — the session keeps
    training on its previous code, so a supervisor (the orchestrator)
    can log the failure and keep the episode alive.  Structured fields:

      * ``constraint`` — which deployment constraint broke:
        ``"uniform_load"`` (grouped per-edge loads under a dist mode),
        ``"pp"`` (pipeline row/stage divisibility for the new load D),
        or ``"topology"`` (a supplied cluster's tree does not match),
      * ``topo`` — the surviving :class:`Topology` the plan was for.
    """

    def __init__(self, message: str, *, constraint: str, topo):
        super().__init__(message)
        self.constraint = constraint
        self.topo = topo


def _step_rng(seed: int, step: int) -> np.random.Generator:
    """Per-step straggler RNG: resume replays the exact pattern sequence
    (bit-for-bit kill/resume needs history-independent sampling)."""
    return np.random.default_rng(np.random.SeedSequence([seed, 7919, step]))


def _code_desc(code) -> Dict:
    """The checkpointed code descriptor: enough to rebuild the deployed
    code deterministically (grouped codes add their per-edge vector)."""
    d = {"s_e": code.tol.s_e, "s_w": code.tol.s_w, "K": code.K}
    vec = getattr(code.tol, "s_w_vec", None)
    if vec is not None:
        d["s_w_vec"] = [int(s) for s in vec]
    return d


def build_coded_batch(code: HGCCode, streams, fast_e, fast_w, seq_len,
                      with_lam: bool = True):
    """Global batch = all workers' assigned-part examples.

    ``with_lam=True`` (single-host path): weights carry coeff × λ so the
    jit gradient reduction decodes implicitly; straggling workers get
    weight 0 (their rows still flow through the step fn — shapes are
    static, only weights change).  ``with_lam=False`` (``--dist``
    paths): weights carry the coding coefficients only — λ is applied
    inside the shard_map decode, per shard group.  Example order is
    (pod, data)-major either way, so sharding the batch dim over
    ("pod", "data") hands worker (i, j) exactly its own examples.
    """
    lam = code.collapsed_weights(fast_e, fast_w) if with_lam else None
    tokens, targets, weights = [], [], []
    topo = code.topo
    for i in range(topo.n):
        for j in range(topo.m[i]):
            w_idx = topo.flat_index(i, j)
            coeff = code.worker_coeffs(i, j)
            for k in code.assignment.worker_parts(i, j):
                b = streams[k].next_batch()
                tokens.append(b["tokens"])
                targets.append(b["targets"])
                w = b["weights"] * float(coeff[k])
                if lam is not None:
                    w = w * float(lam[w_idx])
                weights.append(w)
    return {
        "tokens": np.concatenate(tokens, 0),
        "targets": np.concatenate(targets, 0),
        "weights": np.concatenate(weights, 0),
        # fixed normalizer keeps the loss linear in the weights (exact
        # coded decode); K parts × per-part token count
        "denom": np.float32(
            code.K * tokens[0].shape[0] * seq_len
        ),
    }


def _extend_streams(streams, K: int, vocab: int, part_batch: int,
                    seq_len: int, seed: int):
    """K growth (replan / restored checkpoint) REUSES the existing part
    streams — only the new parts get fresh resumable streams."""
    while len(streams) < K:
        streams.append(
            TokenStream(vocab, part_batch, seq_len,
                        seed=seed * 1000 + len(streams))
        )


class CodedSession:
    """One coded train/serve session over a :class:`CodedCluster`.

    ``cluster=None`` builds a serve-only session (no planning, no data
    streams, no train step) — the serving driver's mode.
    """

    def __init__(
        self,
        cluster: Optional[CodedCluster],
        cfg: ModelConfig,
        *,
        planner: Any = "jncss",
        mode: str = "off",
        tp: int = 1,
        seq_shard: Optional[bool] = None,
        pp: int = 1,
        microbatches: int = 0,
        seq_len: int = 64,
        part_batch: int = 1,
        K: int = 0,
        optimizer: str = "adamw",
        lr: float = 1e-2,
        total_steps: int = 100,
        warmup_steps: Optional[int] = None,
        grad_clip: float = 1.0,
        grad_block: int = 64,
        grad_compression: str = "",
        seed: int = 0,
        scheme: Optional[str] = None,
        checkpoint_dir: str = "",
        checkpoint_every: int = 25,
        keep_checkpoints: int = 3,
        resume: bool = False,
        log_every: int = 10,
        verbose: bool = True,
    ):
        if mode not in ("off", "coded", "coded_int8", "coded_q"):
            raise ValueError(f"unknown session mode {mode!r}")
        # codec for the compressed cross-pod hop: "coded_int8" pins
        # int8 (back-compat spelling); "coded_q" reads grad_compression
        # (default int8, or int4 / fp8 — see dist/compression.py)
        if mode == "coded_int8":
            if grad_compression and grad_compression != "int8":
                raise ValueError(
                    "mode='coded_int8' pins grad_compression='int8'; "
                    "use mode='coded_q' to pick a codec"
                )
            self.grad_compression = "int8"
        elif mode == "coded_q":
            self.grad_compression = grad_compression or "int8"
            from repro.dist import compression as _comp

            if self.grad_compression not in _comp.COMPRESSION_MODES:
                raise ValueError(
                    f"unknown grad_compression "
                    f"{self.grad_compression!r} (choose from "
                    f"{_comp.COMPRESSION_MODES})"
                )
        else:
            if grad_compression:
                raise ValueError(
                    f"grad_compression={grad_compression!r} needs "
                    "mode='coded_q' (or 'coded_int8')"
                )
            self.grad_compression = "none"
        self.cluster = cluster
        self.cfg = cfg
        self.mode = mode
        self.tp = max(int(tp), 1)
        # --seq-shard precedence: an explicit flag (True/False) wins;
        # None falls back to the TrainConfig-level default.  A config-
        # level True quietly stays off where SP cannot apply (tp <= 1 /
        # mode off); an EXPLICIT True there is a flag error instead.
        self._seq_shard_explicit = seq_shard is not None
        self.seq_shard = bool(
            seq_shard if seq_shard is not None
            else TrainConfig.__dataclass_fields__[
                "seq_shard_activations"].default
        )
        self.pp = max(int(pp), 1)
        self.microbatches = max(int(microbatches), 0)
        if self.microbatches and self.pp <= 1:
            raise ValueError(
                "microbatches requires pp > 1 (the pipeline microbatch "
                "count only applies to the stage pipeline; the "
                "single-host accumulation knob is TrainConfig.microbatch)"
            )
        self.seq_len = seq_len
        self.part_batch = part_batch
        self.seed = seed
        self.log_every = log_every
        self.verbose = verbose
        self.losses: List[float] = []
        self._serve_cache: Dict = {}
        self._eval_fn = None

        # model state (shared by train and serve paths)
        rng = jax.random.PRNGKey(seed)
        self.params = tf.init_params(rng, cfg)

        if cluster is None:  # serve-only session: no optimizer, no plan
            self.plan = None
            self.code = None
            self.tcfg = None
            self._optimizer = None
            self.opt_state = None
            self.store = None
            self._step = 0
            self._mesh = None
            return
        self._optimizer = make_optimizer(optimizer)

        # ---- plan the code ------------------------------------------
        self.planner: Planner = get_planner(planner)
        topo = cluster.topo
        K_target = K or self.planner.initial_K(topo)
        self.plan = self.planner.plan(cluster.params, K_target, seed=seed)
        self.code = self.plan.code
        self.scheme = scheme or (
            "hgc_jncss" if self.plan.jncss is not None else "hgc"
        )
        if self.verbose:
            if self.plan.jncss is not None:
                print(f"[train] JNCSS chose (s_e={self.code.tol.s_e}, "
                      f"s_w={self.code.tol.s_w}), D={self.code.load}, "
                      f"K={self.code.K}, "
                      f"T̂={self.plan.expected_iteration_ms:.0f} ms")
            else:
                print(f"[train] fixed scheme {self.scheme}: "
                      f"(s_e={self.code.tol.s_e}, "
                      f"s_w={self.code.tol.s_w}), D={self.code.load}, "
                      f"K={self.code.K}")

        self.tcfg = TrainConfig(
            optimizer=optimizer, lr=lr, total_steps=total_steps,
            warmup_steps=(warmup_steps if warmup_steps is not None
                          else max(total_steps // 10, 1)),
            grad_clip=grad_clip,
            scheme=self.scheme, s_e=self.code.tol.s_e,
            s_w=self.code.tol.s_w, K=self.code.K,
            dist_mode=mode,
            grad_compression=self.grad_compression,
            grad_compression_block=grad_block,
            seq_shard_activations=self.seq_shard,
            pp_stages=self.pp,
            microbatches=self.microbatches,
        )

        # ---- data: one resumable stream per dataset part -------------
        self.streams: List[TokenStream] = []
        _extend_streams(self.streams, self.code.K, cfg.vocab, part_batch,
                        seq_len, seed)

        # ---- init / resume -------------------------------------------
        self.opt_state = self._optimizer.init(self.params)
        self._step = 0
        self.store = None
        self._restored_extra: Dict = {}
        if checkpoint_dir:
            # hash the MODEL config only: run hyperparameters
            # (total_steps, lr schedule) legitimately change across
            # restarts
            self.store = CheckpointStore(
                checkpoint_dir, keep=keep_checkpoints,
                cfg_hash=config_hash(cfg),
            )
            if resume and self.store.latest_step() is not None:
                self._resume()
        self.checkpoint_every = checkpoint_every

        self._setup_train_step()

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def _resume(self):
        start, state, extra = self.store.restore()
        self._restored_extra = extra
        self.params = jax.tree.map(jnp.asarray, state["params"])
        if "opt_state" in state:
            # stateless optimizers (sgd) flatten to an empty subtree —
            # the freshly initialized opt_state is already correct then
            self.opt_state = jax.tree.map(jnp.asarray,
                                          state["opt_state"])
        cl = extra.get("cluster")
        if cl and (cl.get("dead_edges") or cl.get("dead_workers")):
            # the run had shrunk past permanent failures before the
            # kill — rebuild the surviving cluster from the base model
            self.cluster = self.cluster.restored(cl)
            if self.verbose:
                print(f"[train] restored shrunk topology "
                      f"m={self.cluster.topo.m}")
        ck = extra.get("code")
        if ck and (
            ck != _code_desc(self.code)
            or self.code.topo != self.cluster.topo
        ):
            # the run had replanned before the kill — rebuild the
            # deployed code deterministically (same seed ⇒ same code)
            if "s_w_vec" in ck:
                from repro.core.grouping import (
                    GroupedHGCCode, GroupTolerance, price_grouped,
                )

                self.code = GroupedHGCCode.build(
                    self.cluster.topo,
                    GroupTolerance(ck["s_e"], tuple(ck["s_w_vec"])),
                    K=ck["K"], seed=self.seed,
                )
                priced = price_grouped(
                    self.cluster.params, self.code.tol, self.code.loads
                )
            else:
                self.code = HGCCode.build(
                    self.cluster.topo, Tolerance(ck["s_e"], ck["s_w"]),
                    K=ck["K"], seed=self.seed,
                    construction=getattr(self.planner, "construction",
                                         "random"),
                )
                priced = price_tolerance(
                    self.cluster.params, self.code.tol, self.code.load
                )
            # keep the plan (the public λ provider) in lockstep with
            # the actually deployed code
            self.plan = Plan(
                code=self.code, tol=self.code.tol, K=self.code.K,
                expected_iteration_ms=priced,
                jncss=None,
            )
            if self.verbose:
                print(f"[train] restored replanned code "
                      f"(s_e={ck['s_e']}, s_w={ck['s_w']}, K={ck['K']})")
        saved_streams = extra["streams"]
        # the saved list may exceed code.K (a replan once grew K and
        # later shrank it — streams are never discarded)
        _extend_streams(self.streams,
                        max(self.code.K, len(saved_streams)),
                        self.cfg.vocab, self.part_batch, self.seq_len,
                        self.seed)
        for k, sd in enumerate(saved_streams):
            self.streams[k].load_state_dict(sd)
        if "detector" in extra:
            self.cluster.detector.load_state_dict(extra["detector"])
        self._step = start
        if self.verbose:
            print(f"[train] resumed from step {start}")

    # ------------------------------------------------------------------
    # step compilation (mesh, shardings, λ / EF residuals)
    # ------------------------------------------------------------------
    def _setup_train_step(self):
        """Jit the train step; in the dist modes build the mesh, shard
        the state onto it and PIN the output shardings — outputs land in
        exactly the input layouts, so step 2 reuses step 1's executable
        (the zero-recompile invariant)."""
        from repro.launch import steps as steps_lib

        topo = self.cluster.topo
        # a rebuild after shrink() carries the surviving pods' EF
        # residual rows through; the first build starts empty
        carry_residual = getattr(self, "residual", {}) or {}
        self.residual: Dict = {}
        self._batch_sh = self._lam_sh = None
        if self.mode == "off":
            self._mesh = None
            if self.tp > 1:
                raise ValueError(
                    "tp > 1 requires a dist mode (the single-host "
                    "reference loop has no model mesh axis)"
                )
            if self.seq_shard and self._seq_shard_explicit:
                raise ValueError(
                    "--seq-shard requires a dist mode (sequence "
                    "sharding rides the 'model' mesh axis)"
                )
            if self.pp > 1:
                raise ValueError(
                    "pp > 1 requires a dist mode (the pipeline runs "
                    "over the 'stage' mesh axis inside shard_map)"
                )
            self.train_step = jax.jit(
                steps_lib.make_train_step(self.cfg, self.tcfg,
                                          optimizer=self._optimizer)
            )
            return

        if len(set(topo.m)) != 1:
            raise ValueError(
                f"dist modes need a uniform topology for the "
                f"(pod, data) mesh, got m={topo.m}"
            )
        self._require_dist_uniform_load(self.code)
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.dist import compression as comp_lib
        from repro.dist import grad_sync
        from repro.dist import sharding as shard_lib
        from repro.dist.mesh import make_test_mesh

        self._grad_sync = grad_sync
        pods, data = topo.n, topo.m[0]
        shard_lib.validate_tp(self.cfg, self.tp)
        if self.seq_shard and (self.tp > 1 or self._seq_shard_explicit):
            # validate_tp-style clear errors: tp>1 requirement +
            # seq % tp divisibility (+ the recurrent fallback warning)
            shard_lib.validate_seq_shard(self.cfg, self.tp, self.seq_len)
        self._validate_pp(self.code)
        mesh = self._mesh = make_test_mesh(pods, data, self.tp,
                                           stages=self.pp)
        if self.verbose:
            print(f"[train] dist={self.mode}: mesh "
                  + (f"(stage={self.pp} × " if self.pp > 1 else "(")
                  + f"pod={pods} × data={data} × "
                  f"model={self.tp}), "
                  f"grad_compression={self.tcfg.grad_compression}"
                  + (f", TP degree {self.tp}" if self.tp > 1 else "")
                  + (", seq-parallel activations"
                     if self.seq_shard and self.tp > 1 else "")
                  + (f", pipeline stages {self.pp} × "
                     f"{self.microbatches or self.pp} microbatches"
                     if self.pp > 1 else ""))

        param_sh, opt_sh = shard_lib.state_shardings(
            self.params, self.opt_state, self.cfg, mesh,
            fsdp=self.tcfg.fsdp, head_aligned=True,
        )
        self.params = jax.device_put(self.params, param_sh)
        self.opt_state = jax.device_put(self.opt_state, opt_sh)
        dp = ("pod", "data")
        self._batch_sh = {
            "tokens": NamedSharding(mesh, P(dp, None)),
            "targets": NamedSharding(mesh, P(dp, None)),
            "weights": NamedSharding(mesh, P(dp, None)),
            "denom": NamedSharding(mesh, P()),
        }
        self._lam_sh = NamedSharding(mesh, P("pod", "data"))
        res_sh: Dict = {}
        if self.tcfg.grad_compression != "none":
            if carry_residual:
                self.residual = jax.tree.map(jnp.asarray, carry_residual)
            elif "ef_residual" in self._restored_extra:
                # consume the checkpoint payload: a later mesh rebuild
                # must carry the LIVE residual, not roll back to this
                self.residual = jax.tree.map(
                    jnp.asarray, self._restored_extra.pop("ef_residual")
                )
            else:
                self.residual = comp_lib.init_pod_residuals(
                    self.params, pods
                )
            # under TP the residual follows its gradient leaf onto the
            # model axis (same pspec rules as the step's shard_map)
            res_sh = shard_lib.to_shardings(
                shard_lib.residual_pspecs(self.params, self.cfg, mesh,
                                          fsdp=self.tcfg.fsdp),
                mesh,
            )
            self.residual = jax.device_put(self.residual, res_sh)
        self.train_step = jax.jit(
            steps_lib._make_dist_train_step(self.cfg, self.tcfg, mesh,
                                            optimizer=self._optimizer),
            out_shardings=(param_sh, opt_sh, res_sh,
                           NamedSharding(mesh, P())),
        )

    # ------------------------------------------------------------------
    # training
    # ------------------------------------------------------------------
    def build_batch(self, fast_e, fast_w):
        """The coded global batch for one observed straggler pattern."""
        return build_coded_batch(
            self.code, self.streams, fast_e, fast_w, self.seq_len,
            with_lam=(self._mesh is None),
        )

    def _validate_pp(self, code):
        """Clear pp errors up front: group count % stages AND the
        per-group coded batch rows % microbatches.  Re-checked on every
        replan/shrink — a new code's load D changes the row count."""
        if self.pp <= 1:
            return
        from repro.dist import sharding as shard_lib

        loads = getattr(code, "loads", None)
        load = int(loads[0]) if loads else int(code.load)
        shard_lib.validate_pp(
            self.cfg, self.pp,
            microbatches=self.microbatches or self.pp,
            batch_rows=load * self.part_batch,
        )

    def _require_dist_uniform_load(self, code):
        """Dist modes shard the batch dim evenly over (pod, data) — a
        grouped code whose edges carry different loads would misalign
        batch rows with workers.  Uniform-valued grouped plans pass."""
        if self.mode == "off":
            return
        loads = getattr(code, "loads", None)
        if loads is not None and len(set(loads)) > 1:
            counts: Dict[int, int] = {}
            for d in loads:
                counts[int(d)] = counts.get(int(d), 0) + 1
            majority = max(counts, key=lambda d: (counts[d], -d))
            edge, load = next(
                (i, int(d)) for i, d in enumerate(loads)
                if int(d) != majority
            )
            raise ValueError(
                f"dist mode {self.mode!r} shards the coded batch evenly "
                f"over the (pod, data) mesh, which requires every worker "
                f"to carry the same load — but this grouped plan gives "
                f"edge {edge} load D={load} while the majority of edges "
                f"carry D={majority} (per-edge loads: {tuple(loads)}). "
                f"Use a uniform planner, regroup the cluster so loads "
                f"match, or run mode='off'; see docs/planners.md "
                f"(grouped codes under dist modes)"
            )

    def _iteration(self, step: int, force_drop_edge: int = -1,
                   force_drop_step: int = -1, batch=None) -> Dict:
        code, topo = self.code, self.cluster.topo
        fast_e, fast_w, t_iter, wt = sample_straggler_pattern(
            _step_rng(self.seed, step), code, self.cluster.params,
            getattr(code, "load_array", code.load),
        )
        if step == force_drop_step and \
                0 <= force_drop_edge < topo.n and code.tol.s_e > 0:
            # forced straggler drop: exercise the zero-recompile claim —
            # only the λ operand changes, never the compiled step
            fast_e = tuple(
                i for i in range(topo.n) if i != force_drop_edge
            )[: topo.n - code.tol.s_e]
        self.cluster.observe(wt)
        metrics = self._execute(step, fast_e, fast_w, batch)
        metrics["sim_iter_ms"] = t_iter
        metrics["fast_edges"] = fast_e
        return metrics

    def _execute(self, step: int, fast_e, fast_w, batch=None) -> Dict:
        """Dispatch ONE compiled train step under a given completion
        set — the shared tail of :meth:`_iteration` (simulated patterns)
        and :meth:`external_step` (orchestrator-observed patterns)."""
        code, topo = self.code, self.cluster.topo
        if batch is None:
            batch = self.build_batch(fast_e, fast_w)
        if self._mesh is None:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch, jnp.asarray(step)
            )
        else:
            batch = {
                k: jax.device_put(jnp.asarray(v), self._batch_sh[k])
                for k, v in batch.items()
            }
            lam_arr = jax.device_put(
                jnp.asarray(self._grad_sync.lam_array_from_code(
                    code, fast_e, fast_w, topo.n, topo.m[0]
                )),
                self._lam_sh,
            )
            (self.params, self.opt_state, self.residual,
             metrics) = self.train_step(
                self.params, self.opt_state, batch, lam_arr,
                self.residual, jnp.asarray(step),
            )
        self.losses.append(float(metrics["loss"]))
        self._step = step + 1
        return dict(metrics)

    def external_step(self, fast_e, fast_w, *, worker_totals=None,
                      sim_iter_ms: float = 0.0, batch=None) -> Dict:
        """One train step under an EXTERNALLY-observed completion set.

        The orchestrator's entry point: instead of *simulating* a
        straggler pattern from the cluster model (:meth:`step`), the
        caller supplies the completion set it actually waited for —
        ``fast_e`` (edge indices) and ``fast_w`` (per-edge fast-worker
        tuples, indexed by edge for ALL edges) — plus, optionally, the
        flat per-worker runtime observations to feed the detector.
        Identical coded semantics: only the λ operand changes, so the
        compiled step is reused (zero recompiles), and replaying the
        same completion sets into a fresh session reproduces the same
        losses bit-for-bit.
        """
        if self.cluster is None:
            raise RuntimeError("serve-only session (cluster=None) "
                               "cannot train")
        topo = self.cluster.topo
        need_e = topo.n - self.code.tol.s_e
        if len(set(fast_e)) < need_e:
            raise ValueError(
                f"completion set has {len(set(fast_e))} edges; the "
                f"deployed code needs >= {need_e}"
            )
        for i in fast_e:
            need_w = topo.m[i] - self.code.tol.s_w_of(i)
            if len(set(fast_w[i])) < need_w:
                raise ValueError(
                    f"edge {i}: completion set has "
                    f"{len(set(fast_w[i]))} workers; the deployed code "
                    f"needs >= {need_w}"
                )
        if worker_totals is not None:
            self.cluster.observe(worker_totals)
        metrics = self._execute(self._step, tuple(fast_e),
                                [tuple(w) for w in fast_w], batch)
        metrics["sim_iter_ms"] = float(sim_iter_ms)
        metrics["fast_edges"] = tuple(fast_e)
        return metrics

    def step(self, batch=None) -> Dict:
        """One training iteration at the session's current step index.

        Samples a straggler pattern from the cluster model, feeds the
        detector, and runs the compiled step.  ``batch`` overrides the
        coded batch built from the session's part streams — it must be
        in the coded layout of :func:`build_coded_batch`.
        """
        if self.cluster is None:
            raise RuntimeError("serve-only session (cluster=None) "
                               "cannot train")
        return self._iteration(self._step, batch=batch)

    def fit(
        self,
        steps: Optional[int] = None,
        *,
        replan_every: int = 0,
        force_drop_edge: int = -1,
        force_drop_step: int = -1,
        stop_after: int = 0,
    ) -> Dict:
        """The managed loop: straggler simulation → coded step →
        detector feedback → elastic replan → checkpoint.

        ``steps`` is the GLOBAL target step (defaults to the LR
        schedule's ``total_steps``); a resumed session continues from
        its restored step.  ``stop_after`` simulates a kill: exit
        cleanly after N total steps without touching the LR schedule.
        Returns the metrics report (per-step losses + jit cache stats).
        """
        if self.cluster is None:
            raise RuntimeError("serve-only session (cluster=None) "
                               "cannot train")
        total = steps if steps is not None else self.tcfg.total_steps
        start = self._step
        t0 = time.time()
        sim_ms = 0.0
        steps_done = 0
        for step in range(start, total):
            steps_done += 1
            m = self._iteration(step, force_drop_edge, force_drop_step)
            sim_ms += m["sim_iter_ms"]
            if self.verbose and (
                    step % self.log_every == 0 or step == total - 1):
                topo = self.cluster.topo
                drop = sorted(set(range(topo.n)) - set(m["fast_edges"]))
                print(f"[train] step {step:5d} loss {self.losses[-1]:.4f} "
                      f"grad_norm {float(m['grad_norm']):.3f} "
                      f"sim_iter {m['sim_iter_ms']:.0f} ms "
                      f"stragglers: edges={drop}")
            if replan_every and (step + 1) % replan_every == 0:
                self.replan()
            # checkpoint AFTER a possible replan so the saved
            # (tolerance, K) is what the surviving run would train with
            if self.store and (step + 1) % self.checkpoint_every == 0:
                self.save_checkpoint(step + 1)
            if stop_after and step + 1 >= stop_after:
                if self.verbose:
                    print(f"[train] stopping after step {step} "
                          f"(simulated kill)")
                break
        cache_entries = self.jit_cache_entries()
        if self.verbose:
            wall = time.time() - t0
            print(f"[train] done: {steps_done} steps in {wall:.1f}s "
                  f"wall, {sim_ms/1e3:.1f}s simulated cluster time, "
                  f"jit cache entries: {cache_entries}")
        return self.report(first_step=start)

    def replan(self, planner: Any = None, cluster: Any = None):
        """Re-run the planner on the detector-updated cluster model;
        a stable plan reuses the deployed code and part streams.

        ``planner`` swaps the session's strategy first (string or
        instance, as in the constructor) — tolerance and λ are runtime
        operands, so a swap that lands on the same code shapes keeps
        the compiled step (zero recompiles).  ``cluster`` swaps the
        session's cluster model first — the orchestrator's fit-replan
        hook: hand in ``CodedCluster.from_observations(...)`` and the
        plan prices MEASURED delays instead of priors.  The swapped
        cluster must keep the deployed topology (a topology change is
        :meth:`shrink`, not a replan).

        A plan the deployed session cannot run (grouped loads under a
        dist mode, a pipeline-incompatible load) raises a structured
        :class:`ReplanError` and leaves the session on its previous
        plan."""
        if planner is not None:
            self.planner = get_planner(planner)
        if cluster is not None:
            if cluster.topo != self.cluster.topo:
                raise ReplanError(
                    f"replan cluster has topology m={cluster.topo.m}, "
                    f"session is deployed on m={self.cluster.topo.m} — "
                    f"use shrink() for topology changes",
                    constraint="topology", topo=self.cluster.topo,
                )
            self.cluster = cluster
        plan = self.planner.plan(
            self.cluster.updated_params(self.code.load), self.code.K,
            seed=self.seed, reuse=self.code,
        )
        if plan.code is not self.code:
            self._check_deployable(plan.code)
            if self.verbose:
                print(f"[train] replan: tolerance → "
                      f"(s_e={plan.tol.s_e}, s_w={plan.tol.s_w}), "
                      f"K={plan.K}, "
                      f"T̂={plan.expected_iteration_ms:.0f} ms")
            self.plan = plan
            self.code = plan.code
            # the compatible K for the new tolerance may exceed the old
            # one — existing part streams are reused, only the new
            # parts get streams
            _extend_streams(self.streams, self.code.K, self.cfg.vocab,
                            self.part_batch, self.seq_len, self.seed)
        return self.plan

    def _check_deployable(self, code) -> None:
        """Validate a REPLACEMENT code against the deployed session;
        failures surface as structured :class:`ReplanError` (the
        construction path keeps plain ``ValueError`` — there is no
        surviving plan to fall back to at construction time)."""
        try:
            self._require_dist_uniform_load(code)
        except ValueError as err:
            raise ReplanError(str(err), constraint="uniform_load",
                              topo=self.cluster.topo) from err
        try:
            self._validate_pp(code)
        except ValueError as err:
            raise ReplanError(str(err), constraint="pp",
                              topo=self.cluster.topo) from err

    def shrink(self, dead_edges=(), dead_workers=()):
        """Drop PERMANENTLY failed nodes, replan, and keep training.

        Transient stragglers need no action (the code tolerates them by
        construction); a permanent failure shrinks the cluster model,
        re-plans the tolerance on the survivors, and — in the dist
        modes — rebuilds the mesh and re-shards the (topology-
        independent) model state onto it.  One legitimate recompile;
        the shrink record rides checkpoints, so kill/resume replays the
        surviving cluster exactly.
        """
        old_topo = self.cluster.topo
        old_cluster = self.cluster
        keep = [i for i in range(old_topo.n) if i not in set(dead_edges)]
        self.cluster = self.cluster.shrink(dead_edges, dead_workers)
        try:
            plan = self.planner.plan(
                self.cluster.params, self.code.K, seed=self.seed,
            )
            self._check_deployable(plan.code)
        except ReplanError:
            self.cluster = old_cluster
            raise
        except ValueError as err:
            # the survivors cannot host ANY compatible plan (e.g. the
            # shrink made K incompatible with every tolerance level) —
            # keep the pre-shrink session intact and report what broke
            self.cluster = old_cluster
            raise ReplanError(
                str(err), constraint="plan",
                topo=old_cluster.shrink(dead_edges, dead_workers).topo,
            ) from err
        self.plan = plan
        self.code = self.plan.code
        _extend_streams(self.streams, self.code.K, self.cfg.vocab,
                        self.part_batch, self.seq_len, self.seed)
        if self.verbose:
            print(f"[train] shrink: topology → m={self.cluster.topo.m}, "
                  f"(s_e={self.code.tol.s_e}, s_w={self.code.tol.s_w}), "
                  f"K={self.code.K}")
        if self._mesh is not None:
            # surviving pods keep their own EF residual rows
            if self.residual:
                idx = np.asarray(keep, np.intp)
                self.residual = jax.tree.map(
                    lambda r: np.asarray(r)[idx], self.residual
                )
            self.params = jax.tree.map(np.asarray, self.params)
            self.opt_state = jax.tree.map(np.asarray, self.opt_state)
            self._setup_train_step()
        return self.plan

    # ------------------------------------------------------------------
    # checkpointing / reporting
    # ------------------------------------------------------------------
    def save_checkpoint(self, step: Optional[int] = None) -> str:
        if self.store is None:
            raise RuntimeError("session has no checkpoint_dir")
        # detector rides the top-level key only (the cluster snapshot
        # would duplicate it — one source of truth)
        cluster_state = self.cluster.state_dict()
        cluster_state.pop("detector", None)
        extra = {
            "streams": [s.state_dict() for s in self.streams],
            "detector": self.cluster.detector.state_dict(),
            "code": _code_desc(self.code),
            "cluster": cluster_state,
        }
        if self.tcfg.grad_compression != "none" and self._mesh is not None:
            extra["ef_residual"] = self.residual
        return self.store.save(
            self._step if step is None else step,
            {"params": self.params, "opt_state": self.opt_state},
            extra=extra,
        )

    def jit_cache_entries(self) -> int:
        """Compiled-executable count of the train step (-1: unavailable
        on this jax).  1 after a run == the zero-recompile invariant."""
        size_fn = getattr(self.train_step, "_cache_size", None)
        if callable(size_fn):
            return int(size_fn())
        return -1

    def report(self, first_step: int = 0) -> Dict:
        """The metrics payload the train CLI writes to --metrics-out."""
        return {
            "dist": self.mode,
            "first_step": first_step,
            "losses": self.losses,
            "jit_cache_entries": self.jit_cache_entries(),
        }

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------
    def eval_step(self, batch) -> Dict[str, float]:
        """Loss/metrics of one batch under the current params (no
        update, no coding — plain replicated evaluation)."""
        if self._eval_fn is None:
            cfg = self.cfg

            def eval_fn(params, batch):
                _, m = tf.loss_and_metrics(params, cfg, batch)
                return m

            self._eval_fn = jax.jit(eval_fn)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return {k: float(v)
                for k, v in self._eval_fn(self.params, batch).items()}

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def _serve_fns(self, max_len: int, exact: bool):
        """Compiled (prefill, decode) pair; tensor-parallel when tp > 1.

        tp > 1 builds a serving mesh and pins in/out shardings from the
        SAME pspec rules training partitions from (`serve_shardings`) —
        GSPMD then runs the Megatron TP plan; the function bodies are
        the single-host ones, unchanged.
        """
        key = (max_len, exact, self.tp)
        if key in self._serve_cache:
            return self._serve_cache[key]
        prefill_raw = serving.make_prefill_fn(
            self.cfg, max_len, exact=exact
        )
        decode_raw = serving.make_decode_fn(self.cfg)
        if self.tp <= 1:
            entry = (jax.jit(prefill_raw), jax.jit(decode_raw), None)
        else:
            from repro.dist import sharding as shard_lib
            from repro.dist.mesh import make_serve_mesh

            shard_lib.validate_tp(self.cfg, self.tp)
            mesh = make_serve_mesh(self.tp)
            cache_abs = jax.eval_shape(
                lambda: tf.init_cache(self.cfg, 1, max_len,
                                      dtype="float32")
            )
            param_sh, cache_sh = shard_lib.serve_shardings(
                self.params, cache_abs, self.cfg, mesh
            )
            n_in = 3 if self.cfg.is_encdec else 2
            prefill = jax.jit(
                prefill_raw,
                in_shardings=(param_sh,) + (None,) * (n_in - 1),
                out_shardings=(None, cache_sh),
            )
            decode = jax.jit(
                decode_raw,
                in_shardings=(param_sh, None, cache_sh),
                out_shardings=(None, cache_sh),
            )
            entry = (prefill, decode, (mesh, param_sh))
        self._serve_cache[key] = entry
        return entry

    def generate(
        self,
        prompts,
        gen_len: int,
        max_len: Optional[int] = None,
        *,
        enc_frames=None,
        greedy: bool = True,
        seed: int = 0,
        exact_handoff: bool = False,
    ) -> np.ndarray:
        """Batched generation: bulk prefill → decode loop → (B, gen_len)
        token array.  ``exact_handoff`` forces the token-by-token
        prefill (debug path; also the automatic fallback for recurrent /
        encoder-decoder archs whose states only exist on decode)."""
        prompts = jnp.asarray(prompts, jnp.int32)
        max_len = max_len or int(prompts.shape[1]) + gen_len + 1
        prefill_fn, decode_fn, meshed = self._serve_fns(
            max_len, exact_handoff
        )
        params = self.params
        if meshed is not None:
            from repro.dist.sharding import activation_sharding

            mesh, param_sh = meshed
            # shard the weights once per params version, not per call
            cached = getattr(self, "_serve_params", None)
            if cached is None or cached[0] is not self.params:
                self._serve_params = (
                    self.params, jax.device_put(self.params, param_sh)
                )
            params = self._serve_params[1]
            with mesh, activation_sharding(mesh):
                return serving.generate_tokens(
                    params, self.cfg, prompts, gen_len,
                    prefill_fn=prefill_fn, decode_fn=decode_fn,
                    enc_frames=enc_frames, greedy=greedy, seed=seed,
                )
        return serving.generate_tokens(
            params, self.cfg, prompts, gen_len,
            prefill_fn=prefill_fn, decode_fn=decode_fn,
            enc_frames=enc_frames, greedy=greedy, seed=seed,
        )
