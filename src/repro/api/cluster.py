"""`CodedCluster` — the hierarchical cluster as one public object.

The paper's system is a tree: one master, ``n`` edge nodes, ``m_i``
workers per edge, each with a runtime model (compute rate, link delay,
loss probability).  The repo's low-level pieces (``Topology``,
``ClusterParams``, ``StragglerDetector``, ``shrink_topology``) describe
it; this class OWNS it — construction (homogeneous / heterogeneous /
bootstrapped from observed delays), online observation, drift folding,
permanent-failure shrinking, and the straggler-pattern sampler the
training loop draws from each iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.core.runtime_model import ClusterParams
from repro.core.topology import Topology
from repro.dist.elastic import StragglerDetector, shrink_topology


def sample_straggler_pattern(rng, code, params: ClusterParams, D: float):
    """Sample runtimes, wait per the HGC rule, return the fast sets.

    Returns ``(fast_e, fast_w, T_iter_ms, worker_totals)``: the
    ``n − s_e`` fastest edges, per-edge the ``m_i − s_w`` fastest
    workers, the iteration time (slowest counted edge), and the flat
    eq.-(31) worker totals for detector feeding.
    """
    wt, eu, _ = params.sample_iteration(rng, D)
    topo = code.topo
    s_e = code.tol.s_e
    edge_T = np.empty(topo.n)
    fast_w = []
    off = 0
    for i in range(topo.n):
        mi = topo.m[i]
        # per-edge tolerance: uniform codes return s_w everywhere,
        # grouped codes their own s_w^i
        order = np.argsort(wt[off : off + mi])[: mi - code.tol.s_w_of(i)]
        edge_T[i] = eu[i] + wt[off + order[-1]]
        fast_w.append(tuple(sorted(order.tolist())))
        off += mi
    eorder = np.argsort(edge_T)[: topo.n - s_e]
    fast_e = tuple(sorted(eorder.tolist()))
    return fast_e, fast_w, float(edge_T[eorder[-1]]), wt


class CodedCluster:
    """Topology + runtime model + straggler detector, as one object.

    ``params`` is the CURRENT cluster (post-shrink); ``base_params``
    plus the accumulated ``dead_edges``/``dead_workers`` (in ORIGINAL
    indexing) reconstruct it deterministically — that is what a
    checkpoint persists, so a resumed run rebuilds the exact surviving
    cluster before replaying the straggler-pattern stream.
    """

    def __init__(self, params: ClusterParams, *, alpha: float = 0.3,
                 base_params: Optional[ClusterParams] = None,
                 dead_edges: Tuple[int, ...] = (),
                 dead_workers: Tuple[Tuple[int, int], ...] = ()):
        self.params = params
        self.base_params = base_params if base_params is not None else params
        self.dead_edges = tuple(dead_edges)
        self.dead_workers = tuple(tuple(p) for p in dead_workers)
        self.alpha = float(alpha)
        self.detector = StragglerDetector(params, alpha=alpha)

    # ------------------------------------------------------------------
    # constructors
    # ------------------------------------------------------------------
    @classmethod
    def homogeneous(
        cls, n_edges: int = 2, n_workers: int = 4, *,
        topo: Optional[Topology] = None,
        c: float = 10.0, gamma: float = 0.05, tau_w: float = 50.0,
        p_w: float = 0.2, tau_e: float = 100.0, p_e: float = 0.1,
        alpha: float = 0.3,
    ) -> "CodedCluster":
        """Every node identical.  Coding rarely pays off here: JNCSS
        correctly picks (0, 0) because tolerating an edge only raises
        the load."""
        topo = topo or Topology.uniform(n_edges, n_workers)
        return cls(
            ClusterParams.homogeneous(
                topo, c=c, gamma=gamma, tau_w=tau_w, p_w=p_w,
                tau_e=tau_e, p_e=p_e,
            ),
            alpha=alpha,
        )

    @classmethod
    def hetero(
        cls, n_edges: int = 2, n_workers: int = 4, *,
        topo: Optional[Topology] = None,
        slow_edge: int = -1, slow_tau_e: float = 2000.0,
        slow_p_e: float = 0.4, alpha: float = 0.3, **base_knobs,
    ) -> "CodedCluster":
        """One Type-III-style straggler edge (slow, loss-prone uplink,
        paper §V-A flavor): the regime where JNCSS actually buys edge
        tolerance (s_e ≥ 1)."""
        base = cls.homogeneous(n_edges, n_workers, topo=topo,
                               alpha=alpha, **base_knobs)
        tau_e = base.params.tau_e.copy()
        p_e = base.params.p_e.copy()
        tau_e[slow_edge] = slow_tau_e
        p_e[slow_edge] = slow_p_e
        return cls(
            dataclasses.replace(base.params, tau_e=tau_e, p_e=p_e),
            alpha=alpha,
        )

    @classmethod
    def from_observations(
        cls, topo: Topology, worker_totals: Sequence[Sequence[float]],
        D: float, *, gamma: float = 0.05, tau_w: float = 50.0,
        p_w: float = 0.2, tau_e: float = 100.0, p_e: float = 0.1,
        alpha: float = 0.3,
    ) -> "CodedCluster":
        """Bootstrap a cluster model from observed per-worker totals.

        ``worker_totals`` is an (iterations × total_workers) record of
        eq.-(31) samples at load ``D``; the per-part compute term ``c``
        is fitted so the model's expected totals match the observed
        means (link terms at the provided priors), and the detector is
        warm-started with the observations — the first JNCSS pass then
        plans from measured delays, not priors.
        """
        obs = np.asarray(worker_totals, np.float64)
        if obs.ndim != 2 or obs.shape[1] != topo.total_workers:
            raise ValueError(
                f"worker_totals must be (iters, {topo.total_workers}), "
                f"got {obs.shape}"
            )
        base = ClusterParams.homogeneous(
            topo, c=1.0, gamma=gamma, tau_w=tau_w, p_w=p_w,
            tau_e=tau_e, p_e=p_e,
        )
        # E[total] = c·D + 1/γ + link terms  ⇒  c = (mean − rest)/D
        rest = base.expected_worker_total(D) - base.c * D
        c = np.maximum((obs.mean(axis=0) - rest) / max(D, 1e-12), 1e-6)
        cluster = cls(dataclasses.replace(base, c=c), alpha=alpha)
        for row in obs:
            cluster.observe(row)
        return cluster

    # ------------------------------------------------------------------
    @property
    def topo(self) -> Topology:
        return self.params.topo

    def observe(self, worker_totals: Sequence[float]) -> None:
        """Feed one iteration's flat worker totals to the detector."""
        self.detector.observe(worker_totals)

    def updated_params(self, D_ref: float) -> ClusterParams:
        """Cluster model with observed positive drift folded into ``c``
        (what a replan should price)."""
        return self.detector.updated_params(D_ref)

    def sample_pattern(self, rng, code, D=None):
        """One iteration's straggler pattern under the deployed code.

        ``D`` defaults to the code's per-worker load — the flat array
        for grouped codes (edges may carry different loads), the scalar
        otherwise.
        """
        if D is None:
            D = getattr(code, "load_array", code.load)
        return sample_straggler_pattern(rng, code, self.params, D)

    # ------------------------------------------------------------------
    # permanent failures
    # ------------------------------------------------------------------
    def shrink(
        self,
        dead_edges: Iterable[int] = (),
        dead_workers: Iterable[Tuple[int, int]] = (),
    ) -> "CodedCluster":
        """Cluster with permanently failed nodes removed (fresh detector).

        Indices are in the CURRENT cluster's numbering; the returned
        cluster's ``dead_edges``/``dead_workers`` are re-expressed in
        ORIGINAL (base) numbering so the failure record composes across
        repeated shrinks and survives checkpointing.
        """
        dead_e = sorted(set(dead_edges))
        dead_w = sorted(set(tuple(p) for p in dead_workers))
        # current → original numbering, for edges AND workers (a prior
        # worker shrink re-indexes the survivors within its edge)
        prior_w = set(self.dead_workers)
        alive = [i for i in range(self.base_params.topo.n)
                 if i not in self.dead_edges]
        orig_dead_e = self.dead_edges + tuple(alive[i] for i in dead_e)

        def orig_worker(i, j):
            I = alive[i]
            alive_ws = [J for J in range(self.base_params.topo.m[I])
                        if (I, J) not in prior_w]
            return I, alive_ws[j]

        orig_dead_w = self.dead_workers + tuple(
            orig_worker(i, j) for (i, j) in dead_w
        )
        new_params = shrink_topology(
            self.base_params, dead_edges=orig_dead_e,
            dead_workers=orig_dead_w,
        )
        return CodedCluster(
            new_params, alpha=self.alpha, base_params=self.base_params,
            dead_edges=orig_dead_e, dead_workers=orig_dead_w,
        )

    # ------------------------------------------------------------------
    # persistence (checkpoint ``extra`` payload)
    # ------------------------------------------------------------------
    def state_dict(self) -> Dict:
        return {
            "dead_edges": list(self.dead_edges),
            "dead_workers": [list(p) for p in self.dead_workers],
            "detector": self.detector.state_dict(),
        }

    def restored(self, d: Dict) -> "CodedCluster":
        """Cluster rebuilt from a checkpoint snapshot (same base)."""
        cluster = CodedCluster(
            shrink_topology(
                self.base_params,
                dead_edges=d.get("dead_edges", ()),
                dead_workers=[tuple(p) for p in d.get("dead_workers", ())],
            ) if (d.get("dead_edges") or d.get("dead_workers"))
            else self.base_params,
            alpha=self.alpha,
            base_params=self.base_params,
            dead_edges=tuple(d.get("dead_edges", ())),
            dead_workers=tuple(tuple(p) for p in d.get("dead_workers", ())),
        )
        if "detector" in d:
            cluster.detector.load_state_dict(d["detector"])
        return cluster

    def __repr__(self) -> str:
        return (f"CodedCluster(m={self.topo.m}, "
                f"dead_edges={list(self.dead_edges)}, "
                f"observations={self.detector.n_obs})")
