"""Ahead-of-time analysis: lower + compile one (arch × shape × mesh)
cell and extract memory / cost / collective / roofline numbers, using
``ShapeDtypeStruct`` inputs only (no allocation).

This is the public home of the dry-run machinery
(``repro.launch.dryrun`` is now just the CLI around :func:`run_cell`).
NOTE: the production meshes need 256/512 host devices — the CALLER must
set ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax
initializes (the dryrun CLI does).
"""
from __future__ import annotations

import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import get_config, shape_applicable
from repro.dist import sharding as sh
from repro.launch import hlo_analysis
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh

# TPU v5e hardware constants (assignment §ROOFLINE)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link


def _tree_bytes(tree) -> float:
    return float(sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(tree)))


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    fsdp: bool = True,
    microbatch: int = 32,
    remat: bool = True,
    flash: bool = False,
    sharded_accum: bool = False,
    kv_repeat: bool = False,
    remat_policy: str = "full",
    mode: str = "2d",
    moe_ep_axis: str = "model",
    seq_shard: bool = False,
    verbose: bool = True,
) -> Dict:
    """Lower + compile one (arch × shape × mesh) cell; returns the record."""
    import dataclasses as _dc

    cfg = get_config(arch)
    overrides = {}
    if not remat:
        overrides["remat"] = False
    if flash:
        overrides["flash"] = True
    if remat_policy != "full":
        overrides["remat_policy"] = remat_policy
    if kv_repeat and cfg.n_kv_heads and cfg.n_heads >= 8:
        # Head alignment to the TP degree (§Perf): replicate KV heads
        # and zero-pad Q heads up to multiples of 16.  Misaligned heads
        # (llama4: 40 q / 8 kv on a 16-way model axis) otherwise force
        # GSPMD to shard head_dim and ALL-REDUCE the attention scores
        # (S×T-sized!) every layer.  Zero-padded heads are functionally
        # inert (wq=0 => uniform attn x wo=0 => no contribution).
        overrides["n_kv_heads"] = 16
        if cfg.n_heads % 16:
            overrides["n_heads"] = -(-cfg.n_heads // 16) * 16
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    tcfg = TrainConfig(microbatch=microbatch)
    t0 = time.time()
    rec = {
        "arch": arch, "shape": shape_name,
        "multi_pod": multi_pod, "mesh": str(dict(mesh.shape)),
        "fsdp": fsdp, "microbatch": microbatch, "remat": remat,
        "flash": flash, "sharded_accum": sharded_accum,
        "kv_repeat": kv_repeat, "remat_policy": remat_policy,
        "mode": mode, "moe_ep_axis": moe_ep_axis,
        "seq_shard": seq_shard,
    }
    if seq_shard:
        # the pjit counterpart of the dist path's --seq-shard: the
        # activation anchors pin the SEQ dim (not the feature dim) to
        # "model" between the TP collective pairs, so GSPMD lowers the
        # row-parallel all-reduces as reduce-scatter + all-gather and
        # the inter-block activations hold 1/tp of the sequence
        if mode == "dp_only":
            raise ValueError(
                "seq_shard needs tensor parallelism (mode='dp_only' "
                "has no model-sharded activations to seq-shard)"
            )
        sh.validate_seq_shard(cfg, int(mesh.shape.get("model", 1)),
                              shape.seq_len)
    try:
        dp_override = tuple(mesh.axis_names) if mode == "dp_only" else None
        with mesh, sh.activation_sharding(
                mesh, dp=dp_override, tp=(mode != "dp_only"),
                seq=seq_shard):
            if shape.kind in ("train", "prefill"):
                params_abs, opt_abs = steps_lib.abstract_state(cfg, tcfg)
                pspecs = sh.fit_pspecs(
                    sh.params_pspecs(params_abs, cfg, mesh, fsdp=fsdp,
                                     mode=mode, moe_ep_axis=moe_ep_axis),
                    params_abs, mesh,
                )
                p_sh = sh.to_shardings(pspecs, mesh)
                batch_abs = steps_lib.input_specs(cfg, shape)
                bsp_all = sh.batch_pspecs(cfg, mesh)
                if mode == "dp_only":
                    from jax.sharding import PartitionSpec as _P
                    bsp_all = {
                        k: _P(dp_override, *list(v)[1:])
                        for k, v in bsp_all.items()
                    }
                bspecs = {k: v for k, v in bsp_all.items()
                          if k in batch_abs}
                bspecs = sh.fit_pspecs(bspecs, batch_abs, mesh)
                b_sh = sh.to_shardings(bspecs, mesh)
                if shape.kind == "train":
                    ospecs = sh.fit_pspecs(
                        sh.opt_state_pspecs(opt_abs, pspecs), opt_abs, mesh
                    )
                    o_sh = sh.to_shardings(ospecs, mesh)
                    step_fn = steps_lib.make_train_step(
                        cfg, tcfg,
                        accum_shardings=p_sh if sharded_accum else None,
                    )
                    jitted = jax.jit(
                        step_fn,
                        in_shardings=(p_sh, o_sh, b_sh, None),
                        out_shardings=(p_sh, o_sh, None),
                        donate_argnums=(0, 1),
                    )
                    lowered = jitted.lower(
                        params_abs, opt_abs, batch_abs,
                        jax.ShapeDtypeStruct((), jnp.int32),
                    )
                else:
                    step_fn = steps_lib.make_prefill_step(cfg)
                    cache_abs = jax.eval_shape(step_fn, params_abs,
                                               batch_abs)[1]
                    cspecs = sh.fit_pspecs(
                        sh.cache_pspecs(cache_abs, mesh), cache_abs, mesh
                    )
                    c_sh = sh.to_shardings(cspecs, mesh)
                    jitted = jax.jit(
                        step_fn, in_shardings=(p_sh, b_sh),
                        out_shardings=(None, c_sh),
                    )
                    lowered = jitted.lower(params_abs, batch_abs)
            else:  # decode
                params_abs, _ = steps_lib.abstract_state(cfg, TrainConfig())
                pspecs = sh.fit_pspecs(
                    sh.params_pspecs(params_abs, cfg, mesh, fsdp=False),
                    params_abs, mesh,
                )
                p_sh = sh.to_shardings(pspecs, mesh)
                cache_abs = steps_lib.abstract_cache(cfg, shape)
                cspecs = sh.fit_pspecs(
                    sh.cache_pspecs(cache_abs, mesh), cache_abs, mesh
                )
                c_sh = sh.to_shardings(cspecs, mesh)
                tok = steps_lib.input_specs(cfg, shape)["token"]
                dp = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
                t_sh = sh.to_shardings(
                    {"token": sh.fit_spec(P(dp, None), tok.shape, mesh)},
                    mesh)["token"]
                step_fn = steps_lib.make_serve_step(cfg)
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(p_sh, c_sh, t_sh),
                    out_shardings=(None, c_sh),
                    donate_argnums=(1,),
                )
                lowered = jitted.lower(params_abs, cache_abs, tok)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # newer jax: one per device
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
            pod_stride = 256 if multi_pod else 10**9
            ana = hlo_analysis.analysis_record(hlo, pod_stride=pod_stride)

        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            # XLA's own numbers (loop bodies counted ONCE — see
            # hlo_analysis docstring) kept for reference:
            "xla_flops": float(cost.get("flops", -1.0)) if cost else -1.0,
            "xla_bytes": float(cost.get("bytes accessed", -1.0))
            if cost else -1.0,
            # trip-count-corrected per-device numbers:
            "flops": ana["flops"],
            "bytes_accessed": ana["bytes_accessed"],
            "bytes_accessed_bf16eq": ana["bytes_accessed_bf16eq"],
            "collectives": ana["collectives"],
            "collective_operand_bytes": ana["collective_operand_bytes"],
            "collective_link_bytes": ana["collective_link_bytes"],
            "collective_link_bytes_bf16eq":
                ana["collective_link_bytes_bf16eq"],
            "cross_pod_link_bytes": ana["cross_pod_link_bytes"],
            "n_devices": mesh.size,
        })
        # ---- roofline terms (seconds) ----
        tokens = shape.global_batch * (
            shape.seq_len if shape.kind in ("train", "prefill") else 1
        )
        total_p, active_p = cfg.param_counts()
        model_flops = (6.0 if shape.kind == "train" else 2.0) \
            * active_p * tokens
        rec["roofline"] = {
            "compute_s": ana["flops"] / PEAK_FLOPS,
            # bf16-equivalent terms: XLA:CPU float-normalization upcasts
            # bf16→f32; the deployment policy is bf16 activations and
            # collectives, so the eq terms are the TPU-faithful ones
            # (raw terms kept alongside).
            "memory_s": ana["bytes_accessed_bf16eq"] / HBM_BW,
            "memory_s_raw": ana["bytes_accessed"] / HBM_BW,
            # projection with the Pallas flash kernel (score traffic
            # retired in VMEM — kernels/flash_attention.py):
            "memory_s_pallas": (ana["bytes_accessed_bf16eq"]
                                - ana.get("attn_bytes_bf16eq", 0.0))
            / HBM_BW,
            "collective_s": ana["collective_link_bytes_bf16eq"] / LINK_BW,
            "collective_s_raw": ana["collective_link_bytes"] / LINK_BW,
            "model_flops_global": model_flops,
            "model_flops_per_device": model_flops / mesh.size,
            "useful_flops_ratio": (model_flops / mesh.size)
            / max(ana["flops"], 1.0),
        }
        dom = max(
            ("compute_s", "memory_s", "collective_s"),
            key=lambda k: rec["roofline"][k],
        )
        rec["roofline"]["dominant"] = dom
        try:
            rec["memory_analysis"] = {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            }
        except Exception:
            rec["memory_analysis"] = str(mem)
        if verbose:
            r = rec["roofline"]
            print(f"[dryrun] {arch} × {shape_name} "
                  f"({'multi' if multi_pod else 'single'}-pod): OK  "
                  f"lower {rec['lower_s']}s compile {rec['compile_s']}s")
            print(f"  memory_analysis: {rec['memory_analysis']}")
            print(f"  flops/device: {rec['flops']:.3e}  "
                  f"bytes/device: {rec['bytes_accessed']:.3e}  "
                  f"coll-link bytes: {rec['collective_link_bytes']:.3e}")
            print(f"  roofline: compute {r['compute_s']*1e3:.1f}ms  "
                  f"memory {r['memory_s']*1e3:.1f}ms  "
                  f"collective {r['collective_s']*1e3:.1f}ms  "
                  f"dominant={r['dominant']}  "
                  f"useful-flops-ratio {r['useful_flops_ratio']:.3f}")
    except Exception as e:
        rec.update({
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        })
        if verbose:
            print(f"[dryrun] {arch} × {shape_name}: FAILED {rec['error']}")
    return rec
