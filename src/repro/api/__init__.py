"""`repro.api` — the public object model of the coded system.

One import surface for everything a user script needs:

  * :class:`CodedCluster` — topology + runtime model + straggler
    detector (``homogeneous`` / ``hetero`` / ``from_observations``),
  * :class:`Plan` + the pluggable :class:`Planner` strategies
    (``jncss`` | ``fixed`` | ``uniform`` | ``grouped`` |
    ``comm_budget``) — cluster model → deployed HGC code + λ provider
    (see ``docs/planners.md`` for the selection guide),
  * :class:`CodedSession` — mesh, sharded state, compiled
    train/eval/generate steps, elastic replan loop, checkpoints
    (``session.fit()``, ``session.step()``, ``session.generate()``),
  * re-exports of the stable core/dist/sim vocabulary (``Topology``,
    ``HGCCode``, ``replan``, ``simulate_training``, …) so examples and
    user code import ONLY ``repro.api`` (plus configs/data).

``repro.api.aot`` (lower/compile/roofline analysis) and
``repro.api.serving`` (prefill/decode builders) are importable
submodules — not pulled in eagerly, they carry the heavier deps.
"""
from repro.core import jncss, tradeoff
from repro.core.hgc import HGCCode
from repro.core.runtime_model import ClusterParams, paper_cluster
from repro.core.topology import Tolerance, Topology
from repro.dist.elastic import (
    Plan,
    StragglerDetector,
    price_tolerance,
    replan,
    shrink_topology,
)
from repro.sim.simulator import simulate_training

from repro.core.grouping import GroupedHGCCode, GroupTolerance

from repro.api.cluster import CodedCluster, sample_straggler_pattern
from repro.api.planner import (
    CommBudgetPlanner,
    FixedPlanner,
    GroupedPlanner,
    JNCSSPlanner,
    Planner,
    UniformPlanner,
    get_planner,
    planner_for_scheme,
)
from repro.api.session import CodedSession, ReplanError, build_coded_batch

__all__ = [
    # the object model
    "CodedCluster",
    "CodedSession",
    "ReplanError",
    "Plan",
    "Planner",
    "JNCSSPlanner",
    "FixedPlanner",
    "UniformPlanner",
    "GroupedPlanner",
    "CommBudgetPlanner",
    "get_planner",
    "planner_for_scheme",
    "build_coded_batch",
    "sample_straggler_pattern",
    # stable re-exported vocabulary
    "Topology",
    "Tolerance",
    "GroupTolerance",
    "HGCCode",
    "GroupedHGCCode",
    "ClusterParams",
    "paper_cluster",
    "StragglerDetector",
    "replan",
    "shrink_topology",
    "price_tolerance",
    "simulate_training",
    "jncss",
    "tradeoff",
]
