"""JNCSS — Jointly Node and Coding Scheme Selection (paper §IV-C).

Algorithm 2 solves  P1: min_{s_e, s_w, e, w} T_tol  exactly (Theorem 2):
for each tolerance pair it evaluates the order-statistic expression

    T̂(s_e, s_w) = min_{(n−s_e)-th} ( A_i + min_{(m_i−s_w)-th} B_(i,j) )

with A_i = τ_i/(1−p_i) and B_(i,j) the expected worker total (eq 43),
then takes the grid minimum.  We provide:

  * :func:`solve`            — vectorized Algorithm 2 (scales to 1000+
                               nodes; the paper's loop form is
                               :func:`solve_reference` for tests),
  * :func:`brute_force`      — exhaustive P2 check used to validate
                               Theorem 2 in the test-suite,
  * :func:`theorem3_gap_bound` — the Theorem 3 a-priori gap bound,
  * :func:`homogeneous_case1` / `homogeneous_case2` — §IV-B closed forms.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import tradeoff
from repro.core.runtime_model import ClusterParams, kth_min
from repro.core.topology import Tolerance, Topology


@dataclasses.dataclass(frozen=True)
class JNCSSResult:
    s_e: int
    s_w: int
    T_tol: float
    D: float
    # selection variables (paper eqs 39/40): 1 = participating non-straggler
    e: Tuple[int, ...]
    w: Tuple[Tuple[int, ...], ...]
    # full grid of T̂(s_e, s_w) for diagnostics / benchmarks
    grid: Optional[np.ndarray] = None


def load_D(topo: Topology, K: int, s_e: int, s_w: int) -> float:
    """eq (44): D = K(s_e+1)(s_w+1)/Σ m_i (fractional in the model)."""
    return K * (s_e + 1) * (s_w + 1) / topo.total_workers


def _edge_scores(
    params: ClusterParams, D: float, s_w: int
) -> Tuple[np.ndarray, np.ndarray]:
    """A_i + (m_i−s_w)-th min_j B_(i,j), and the flat B array."""
    topo = params.topo
    B = params.expected_worker_total(D)
    A = params.expected_edge_upload()
    scores = np.empty(topo.n)
    off = 0
    for i in range(topo.n):
        mi = topo.m[i]
        scores[i] = A[i] + kth_min(B[off : off + mi], mi - s_w)
        off += mi
    return scores, B


def solve(
    params: ClusterParams,
    K: int,
    require_feasible: bool = True,
    integral_D: bool = False,
    with_grid: bool = False,
) -> JNCSSResult:
    """Vectorized Algorithm 2 over the full (s_e, s_w) grid."""
    topo = params.topo
    n, m_min = topo.n, topo.m_min
    grid = np.full((n, m_min), np.inf)
    for s_e in range(n):
        for s_w in range(m_min):
            tol = Tolerance(s_e, s_w)
            if require_feasible and not tradeoff.feasible(topo, tol):
                continue
            D = load_D(topo, K, s_e, s_w)
            if integral_D:
                D = float(np.ceil(D))
            scores, _ = _edge_scores(params, D, s_w)
            grid[s_e, s_w] = kth_min(scores, n - s_e)
    if not np.isfinite(grid).any():
        raise ValueError("no feasible (s_e, s_w) for this topology")
    s_e, s_w = np.unravel_index(np.argmin(grid), grid.shape)
    s_e, s_w = int(s_e), int(s_w)
    T = float(grid[s_e, s_w])
    D = load_D(topo, K, s_e, s_w)
    if integral_D:
        D = float(np.ceil(D))
    e, w = _selection(params, D, s_e, s_w, T)
    return JNCSSResult(
        s_e=s_e,
        s_w=s_w,
        T_tol=T,
        D=D,
        e=e,
        w=w,
        grid=grid if with_grid else None,
    )


def _selection(
    params: ClusterParams, D: float, s_e: int, s_w: int, T_hat: float
) -> Tuple[Tuple[int, ...], Tuple[Tuple[int, ...], ...]]:
    """Algorithm 2 lines 13–21: mark participating nodes/workers."""
    topo = params.topo
    scores, B = _edge_scores(params, D, s_w)
    eps = 1e-12 * max(1.0, abs(T_hat))
    e_sel: List[int] = []
    w_sel: List[Tuple[int, ...]] = []
    n_chosen = 0
    order = np.argsort(scores, kind="stable")
    chosen_edges = set(order[: topo.n - s_e].tolist())
    off = 0
    for i in range(topo.n):
        mi = topo.m[i]
        Bi = B[off : off + mi]
        if i in chosen_edges and scores[i] <= T_hat + eps:
            e_sel.append(1)
            thr = kth_min(Bi, mi - s_w)
            worder = np.argsort(Bi, kind="stable")
            fast = set(worder[: mi - s_w].tolist())
            w_sel.append(tuple(1 if j in fast else 0 for j in range(mi)))
        else:
            e_sel.append(0)
            w_sel.append((0,) * mi)
        off += mi
    return tuple(e_sel), tuple(w_sel)


def solve_reference(params: ClusterParams, K: int) -> JNCSSResult:
    """Direct transliteration of Algorithm 2 (loops, for testing)."""
    topo = params.topo
    best = None
    for s_e in range(topo.n):
        for s_w in range(topo.m_min):
            D = load_D(topo, K, s_e, s_w)
            A = params.expected_edge_upload()
            B = params.expected_worker_total(D)
            per_edge = []
            off = 0
            for i in range(topo.n):
                mi = topo.m[i]
                Bi = sorted(B[off : off + mi])
                per_edge.append(A[i] + Bi[mi - s_w - 1])
                off += mi
            T = sorted(per_edge)[topo.n - s_e - 1]
            if best is None or T < best[0]:
                best = (T, s_e, s_w)
    T, s_e, s_w = best
    D = load_D(topo, K, s_e, s_w)
    e, w = _selection(params, D, s_e, s_w, T)
    return JNCSSResult(s_e=s_e, s_w=s_w, T_tol=float(T), D=D, e=e, w=w)


def brute_force(
    params: ClusterParams, K: int, max_nodes: int = 12
) -> JNCSSResult:
    """Exhaustive search over ALL (s_e, s_w, e, w) — P1 ground truth.

    Exponential; only for small topologies in tests (validates Thm 2).
    """
    topo = params.topo
    if topo.total_workers > max_nodes:
        raise ValueError("brute force limited to tiny topologies")
    A = params.expected_edge_upload()
    best: Optional[Tuple[float, int, int, Tuple, Tuple]] = None
    for s_e in range(topo.n):
        for s_w in range(topo.m_min):
            D = load_D(topo, K, s_e, s_w)
            B = params.expected_worker_total(D)
            f_e = topo.n - s_e
            for edges in itertools.combinations(range(topo.n), f_e):
                # for each chosen edge, all worker subsets of size m_i−s_w
                per_edge_opts = []
                for i in edges:
                    off = sum(topo.m[:i])
                    mi = topo.m[i]
                    opts = []
                    for ws in itertools.combinations(range(mi), mi - s_w):
                        t = A[i] + max(B[off + j] for j in ws)
                        opts.append((t, ws))
                    per_edge_opts.append(min(opts, key=lambda x: x[0]))
                T = max(t for t, _ in per_edge_opts)
                if best is None or T < best[0]:
                    e_vec = tuple(
                        1 if i in edges else 0 for i in range(topo.n)
                    )
                    w_vec: List[Tuple[int, ...]] = []
                    k = 0
                    for i in range(topo.n):
                        if i in edges:
                            ws = per_edge_opts[k][1]
                            k += 1
                            w_vec.append(
                                tuple(
                                    1 if j in ws else 0
                                    for j in range(topo.m[i])
                                )
                            )
                        else:
                            w_vec.append((0,) * topo.m[i])
                    best = (T, s_e, s_w, e_vec, tuple(w_vec))
    T, s_e, s_w, e_vec, w_vec = best
    return JNCSSResult(
        s_e=s_e,
        s_w=s_w,
        T_tol=float(T),
        D=load_D(topo, K, s_e, s_w),
        e=e_vec,
        w=w_vec,
    )


# ----------------------------------------------------------------------
# Theorem 3: a-priori gap bound between T̂ and the stochastic runtime
# ----------------------------------------------------------------------
def order_stat_factor(n: int, r: int) -> float:
    """f(n,r) = sqrt((r−1)/(n(n−r+1))) + sqrt((n−r)/(nr)) (Lemma 1)."""
    if not 1 <= r <= n:
        raise ValueError(f"r={r} outside [1, {n}]")
    return float(
        np.sqrt((r - 1) / (n * (n - r + 1))) + np.sqrt((n - r) / (n * r))
    )


def theorem3_gap_bound(
    params: ClusterParams,
    result: JNCSSResult,
    n_samples: int = 4000,
    seed: int = 0,
) -> float:
    """E|T_tol − T̂| ≤ f(n, n−ŝ_e)·Δ_e + max_i f(m_i, m_i−ŝ_w)·Δ_w^i.

    Δ terms (eq 49) need means/variances of the per-edge totals T^i_tol
    (which include an inner order statistic) — we estimate them by Monte
    Carlo over the runtime model, which is exact in distribution.
    """
    topo = params.topo
    rng = np.random.default_rng(seed)
    s_e, s_w, D = result.s_e, result.s_w, result.D
    W, n = topo.total_workers, topo.n
    worker_samples = np.empty((n_samples, W))
    edge_totals = np.empty((n_samples, n))
    for t in range(n_samples):
        wt, eu, _ = params.sample_iteration(rng, D)
        worker_samples[t] = wt
        off = 0
        for i in range(n):
            mi = topo.m[i]
            edge_totals[t, i] = eu[i] + kth_min(
                wt[off : off + mi], mi - s_w
            )
            off += mi

    def delta(samples: np.ndarray) -> float:
        # eq (49): sqrt( Σ_i [ V[X_i] + (E[X_i] − mean)² ] − k·V[mean] )
        k = samples.shape[1]
        var_i = samples.var(axis=0)
        mean_i = samples.mean(axis=0)
        xbar = samples.mean(axis=1)
        inner = np.sum(var_i + (mean_i - mean_i.mean()) ** 2) - k * xbar.var()
        return float(np.sqrt(max(inner, 0.0)))

    bound = order_stat_factor(n, n - s_e) * delta(edge_totals)
    worst_w = 0.0
    off = 0
    for i in range(n):
        mi = topo.m[i]
        dw = delta(worker_samples[:, off : off + mi])
        worst_w = max(worst_w, order_stat_factor(mi, mi - s_w) * dw)
        off += mi
    return bound + worst_w


# ----------------------------------------------------------------------
# §IV-B homogeneous closed forms
# ----------------------------------------------------------------------
def case1_expected_runtime(
    s_e: int, s_w: int, c: float, K: int, n: int, m: int,
    gamma: float, tau1: float, tau2: float,
) -> float:
    """eq (35): computation-dominated homogeneous expected runtime."""
    k = (n - s_e) * (m - s_w)
    tail = np.log(k) / gamma if k > 1 else 0.0
    return c * K * (s_e + 1) * (s_w + 1) / (n * m) + 2 * tau1 + 2 * tau2 + tail


def homogeneous_case1(
    c: float, K: int, n: int, m: int, gamma: float, tau1: float, tau2: float
) -> Tuple[int, int, float]:
    """§IV-B Case 1: optimum lies at the four corners of the domain."""
    corners = [(0, 0), (n - 1, 0), (0, m - 1), (n - 1, m - 1)]
    vals = [
        (case1_expected_runtime(se, sw, c, K, n, m, gamma, tau1, tau2), se, sw)
        for se, sw in corners
    ]
    v, se, sw = min(vals)
    return se, sw, float(v)


def case2_expected_runtime(
    s_e: int, c: float, K: int, n: int, m: int,
    tau1: float, tau2: float, p2: float,
) -> float:
    """eq (38): communication-dominated homogeneous runtime (s_w = 0)."""
    k = n - s_e
    tail = -2.0 * tau2 / np.log(p2) * np.log(k) if k > 1 else 0.0
    return c * K * (s_e + 1) / (n * m) + 2 * tau1 + tau2 + tail


def homogeneous_case2(
    c: float, K: int, n: int, m: int, tau1: float, tau2: float, p2: float
) -> Tuple[int, int, float]:
    """§IV-B Case 2: optimum at s_e ∈ {0, n−1}, s_w = 0."""
    vals = [
        (case2_expected_runtime(se, c, K, n, m, tau1, tau2, p2), se)
        for se in (0, n - 1)
    ]
    v, se = min(vals)
    return se, 0, float(v)
