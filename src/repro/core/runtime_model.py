"""Per-iteration runtime model — paper §IV-A.

Worker (i,j):
  compute   T_cmp = c_{(i,j)} · D + Exp(γ_{(i,j)})          (eq 28, shifted exp)
  comm      T_com = N · τ_{(i,j)},  N ~ Geom(1−p_{(i,j)})   (eqs 29/30;
            Pr(N=x) = p^{x−1}(1−p), retransmissions on an unreliable link)
  total     T^{(i,j)} = T^i_dl + T^{(i,j)}_dl + T_cmp + T^{(i,j)}_ul  (eq 31)

Edge i:     T^i = T^i_ul + min_{(m_i−s_w)-th} T^{(i,j)}              (eq 32)
System:     T   = min_{(n−s_e)-th} T^i                               (eq 33)

Everything is vectorized numpy (flat worker arrays with an edge index),
so the simulator can run thousands of iterations × schemes quickly and
JNCSS can evaluate big topologies (1000+ node scaling).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.topology import Topology


def kth_min(values: np.ndarray, k: int, axis: int = -1) -> np.ndarray:
    """The paper's ``min_{k-th}``: k-th smallest (1-indexed)."""
    if k < 1:
        raise ValueError("k is 1-indexed and must be ≥ 1")
    return np.partition(values, k - 1, axis=axis).take(k - 1, axis=axis)


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    """Heterogeneous cluster description (flat worker arrays).

    Worker arrays have length ``topo.total_workers`` in
    ``topo.worker_ids()`` order; edge arrays have length ``topo.n``.
    Units follow the paper: milliseconds, rates in 1/ms.
    """

    topo: Topology
    c: np.ndarray        # per-part deterministic compute time (ms)
    gamma: np.ndarray    # exponential rate of stochastic compute (1/ms)
    tau_w: np.ndarray    # worker↔edge single-transmission time (ms)
    p_w: np.ndarray      # worker link failure probability
    tau_e: np.ndarray    # edge↔master single-transmission time (ms)
    p_e: np.ndarray      # edge link failure probability
    # Fan-in contention at the master for the DIRECT worker↔master path
    # (Standard GC): the master is one endpoint serving Σm_i uploads
    # where an edge serves m_i — slowdown ≈ n (paper §I's "severe
    # bottleneck at the master").  0 ⇒ defaults to topo.n.
    master_contention: float = 0.0

    def __post_init__(self):
        W, n = self.topo.total_workers, self.topo.n
        for name, arr, size in [
            ("c", self.c, W),
            ("gamma", self.gamma, W),
            ("tau_w", self.tau_w, W),
            ("p_w", self.p_w, W),
            ("tau_e", self.tau_e, n),
            ("p_e", self.p_e, n),
        ]:
            if np.asarray(arr).shape != (size,):
                raise ValueError(f"{name} must have shape ({size},)")

    # ------------------------------------------------------------------
    @property
    def edge_of(self) -> np.ndarray:
        """Edge index of every flat worker."""
        return np.repeat(np.arange(self.topo.n), np.array(self.topo.m))

    # -------------------- expectations (used by JNCSS) -----------------
    def expected_worker_total(self, D: float) -> np.ndarray:
        """B_{(i,j)} of Algorithm 2 (eq 43 expectation), flat array."""
        e = self.edge_of
        return (
            self.c * D
            + 1.0 / self.gamma
            + 2.0 * self.tau_w / (1.0 - self.p_w)
            + (self.tau_e / (1.0 - self.p_e))[e]
        )

    def expected_edge_upload(self) -> np.ndarray:
        """A_i of Algorithm 2: τ_i/(1−p_i)."""
        return self.tau_e / (1.0 - self.p_e)

    def worker_total_variance(self, D: float = 0.0) -> np.ndarray:
        """Var[T^{(i,j)}] (D enters only the deterministic shift ⇒ unused).

        Var = 1/γ² + 2 τ_w² p_w/(1−p_w)² + τ_e² p_e/(1−p_e)² (independent
        exponential + two geometric links + the edge download hop).
        """
        e = self.edge_of
        var_geo_w = self.tau_w**2 * self.p_w / (1.0 - self.p_w) ** 2
        var_geo_e = (self.tau_e**2 * self.p_e / (1.0 - self.p_e) ** 2)[e]
        return 1.0 / self.gamma**2 + 2.0 * var_geo_w + var_geo_e

    # ----------------------------- sampling ----------------------------
    def sample_iteration(
        self, rng: np.random.Generator, D: float
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One iteration's random times.

        Returns:
          worker_total: flat (W,) — eq (31) totals (incl. edge download),
          edge_upload:  (n,)     — T^i_com,u samples,
          worker_direct:(W,)     — worker↔master totals for Standard GC
                                   (no edge hop: 2 worker-link transfers).
        """
        W = self.topo.total_workers
        t_cmp = self.c * D + rng.exponential(1.0 / self.gamma, size=W)
        # np.Generator.geometric(q) has P(k)=(1−q)^{k−1} q, k≥1 — the
        # paper's distribution with q = 1−p.
        n_dl = rng.geometric(1.0 - self.p_w, size=W)
        n_ul = rng.geometric(1.0 - self.p_w, size=W)
        t_w_comm = (n_dl + n_ul) * self.tau_w
        n_e_dl = rng.geometric(1.0 - self.p_e, size=self.topo.n)
        n_e_ul = rng.geometric(1.0 - self.p_e, size=self.topo.n)
        edge_dl = (n_e_dl * self.tau_e)[self.edge_of]
        worker_total = edge_dl + t_w_comm + t_cmp
        edge_upload = n_e_ul * self.tau_e
        contention = self.master_contention or float(self.topo.n)
        worker_direct = t_w_comm * contention + t_cmp
        return worker_total, edge_upload, worker_direct

    # --------------------------- constructors --------------------------
    @staticmethod
    def homogeneous(
        topo: Topology,
        c: float,
        gamma: float,
        tau_w: float,
        p_w: float,
        tau_e: float,
        p_e: float,
    ) -> "ClusterParams":
        W, n = topo.total_workers, topo.n
        return ClusterParams(
            topo=topo,
            c=np.full(W, c),
            gamma=np.full(W, gamma),
            tau_w=np.full(W, tau_w),
            p_w=np.full(W, p_w),
            tau_e=np.full(n, tau_e),
            p_e=np.full(n, p_e),
        )


def paper_cluster(dataset: str = "mnist") -> ClusterParams:
    """The exact simulation setting of paper §V-A.

    1 master, n=4 edges × m=10 workers.
    Edges:   Type I  ×1: p=0.1, τ=50ms
             Type II ×2: p=0.1, τ=100ms
             Type III×1: p=0.2, τ=500ms
    Workers (per edge): Type I ×5: p=.1, τ=50,  γ=.1
                        Type II ×2: p=.5, τ=100, γ=.1
                        Type III×2: p=.1, τ=50,  γ=.01
                        Type IV ×1: p=.5, τ=100, γ=.01
    c: strong compute 10ms (MNIST) / 100ms (CIFAR); weak 5×.
    "Strong computation" = Types I & II (γ=0.1).
    """
    topo = Topology.uniform(4, 10)
    tau_e = np.array([50.0, 100.0, 100.0, 500.0])
    p_e = np.array([0.1, 0.1, 0.1, 0.2])
    # per-edge worker pattern
    tau_w_edge = [50.0] * 5 + [100.0] * 2 + [50.0] * 2 + [100.0]
    p_w_edge = [0.1] * 5 + [0.5] * 2 + [0.1] * 2 + [0.5]
    gamma_edge = [0.1] * 5 + [0.1] * 2 + [0.01] * 2 + [0.01]
    strong_c = 10.0 if dataset == "mnist" else 100.0
    weak_c = 5.0 * strong_c
    c_edge = [strong_c if g == 0.1 else weak_c for g in gamma_edge]
    n = topo.n
    return ClusterParams(
        topo=topo,
        c=np.array(c_edge * n),
        gamma=np.array(gamma_edge * n),
        tau_w=np.array(tau_w_edge * n),
        p_w=np.array(p_w_edge * n),
        tau_e=tau_e,
        p_e=p_e,
    )


def expected_max_exponential(gamma: float, k: int) -> float:
    """E[max of k iid Exp(γ)] ≈ ln(k)/γ (paper's approximation, §IV-B)."""
    if k <= 0:
        raise ValueError("k must be positive")
    return np.log(max(k, 1)) / gamma if k > 1 else 1.0 / gamma


def expected_max_geometric(p: float, k: int) -> float:
    """E[max of k iid Geom(1−p)] ≈ 1/2 − ln(k)/ln(p) (Eisenberg [20])."""
    if k <= 1:
        return 1.0 / (1.0 - p)
    return 0.5 - np.log(k) / np.log(p)
