"""Heterogeneity-aware grouped HGC — Wang et al. (arXiv:1901.09339) flavor.

The paper's two-layer code carries ONE worker tolerance ``s_w`` for every
edge.  On intra-edge-heterogeneous clusters that is wasteful: an edge
whose workers are uniformly fast gains nothing from worker redundancy,
while an edge with a heavy straggler tail wants a lot of it.  Following
the grouping idea of Wang et al. (group workers by capability, give each
group its own tolerance), we let every edge — the natural group of the
hierarchical topology — carry its own worker tolerance ``s_w^i``:

  * layer 1 is UNCHANGED (``B`` at tolerance ``s_e``, cyclic eq. 15/16
    placement — Condition 1 only involves the edge layer),
  * layer 2 builds each ``D̄^i`` at its own ``s_w^i`` (Condition 2 is
    per-edge), so the per-worker load becomes per-edge:

        D_i = n_i (s_w^i + 1) / m_i = K (s_e + 1)(s_w^i + 1) / Σ m_j .

Exactness: any ≤ s_e straggling edges plus ≤ s_w^i straggling workers
under each surviving edge i decode the exact gradient sum — the decode
is the SAME two-stage λ pipeline, so ``collapsed_weights`` (and with it
``dist/grad_sync``'s runtime-λ operand and the zero-recompile replan)
work unchanged.

:func:`plan_grouped` is the matching planner core: the per-edge choice
decouples (D_i depends only on edge i's own ``s_w^i``), so the joint
optimum is a per-edge argmin inside the JNCSS ``s_e`` grid — and its
expected time is never worse than uniform JNCSS (the uniform vector is
always a candidate).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import tradeoff
from repro.core.assignment import Assignment
from repro.core.encoding import (
    LinearCode,
    build_random_code,
    build_replication_code,
)
from repro.core.hgc import HGCCode
from repro.core.runtime_model import ClusterParams, kth_min
from repro.core.topology import Tolerance, Topology


@dataclasses.dataclass(frozen=True)
class GroupTolerance:
    """Per-edge worker tolerances ``(s_e, (s_w^1, ..., s_w^n))``.

    Duck-compatible with :class:`~repro.core.topology.Tolerance` where
    the session/decode seam reads it: ``.s_e``, ``.s_w`` (the uniform
    guarantee — the minimum over edges) and ``.s_w_of(i)``.
    """

    s_e: int
    s_w_vec: Tuple[int, ...]

    @property
    def s_w(self) -> int:
        """The uniformly guaranteed worker tolerance: min_i s_w^i."""
        return min(self.s_w_vec)

    def s_w_of(self, i: int) -> int:
        return self.s_w_vec[i]

    def validate(self, topo: Topology) -> "GroupTolerance":
        if len(self.s_w_vec) != topo.n:
            raise ValueError(
                f"s_w_vec has {len(self.s_w_vec)} entries for "
                f"{topo.n} edges"
            )
        if not (0 <= self.s_e < topo.n):
            raise ValueError(f"s_e={self.s_e} outside [0:{topo.n})")
        for i, s in enumerate(self.s_w_vec):
            if not (0 <= s < topo.m[i]):
                raise ValueError(
                    f"s_w^{i}={s} outside [0:{topo.m[i]}) at edge {i}"
                )
        # layer-1 feasibility only involves s_e (paper §II-B)
        if not tradeoff.feasible(topo, Tolerance(self.s_e, 0)):
            raise ValueError(
                f"s_e={self.s_e} infeasible for topology {topo.m}"
            )
        return self

    def num_fast_edges(self, topo: Topology) -> int:
        return topo.n - self.s_e

    def num_fast_workers(self, topo: Topology, i: int) -> int:
        return topo.m[i] - self.s_w_vec[i]


def compatible_K_grouped(
    topo: Topology, gtol: GroupTolerance, at_least: int = 1
) -> int:
    """Smallest K ≥ at_least with integral n_i AND per-edge D_i."""
    gtol.validate(topo)
    K = max(1, at_least)
    W = topo.total_workers
    while True:
        ok = True
        for i, mi in enumerate(topo.m):
            num_ni = K * (gtol.s_e + 1) * mi
            if num_ni % W != 0:
                ok = False
                break
            ni = num_ni // W
            if (ni * (gtol.s_w_vec[i] + 1)) % mi != 0:
                ok = False
                break
        if ok:
            return K
        K += 1


def build_grouped_assignment(
    topo: Topology, gtol: GroupTolerance, K: int
) -> Assignment:
    """Cyclic assignment with a per-edge worker cover ``s_w^i + 1``.

    Layer 1 is the paper's eqs (15)/(16) verbatim; layer 2 uses the same
    stride-D_i cyclic windows per edge — m_i contiguous windows of
    length D_i wrap the n_i local parts exactly (s_w^i + 1) times, so
    each edge's local cover is exact at its own tolerance.
    """
    gtol.validate(topo)
    W = topo.total_workers
    edge_parts: List[Tuple[int, ...]] = []
    offset = 0
    for i in range(topo.n):
        num = K * (gtol.s_e + 1) * topo.m[i]
        if num % W != 0:
            raise ValueError(
                f"n_i for edge {i} not integral (K={K}); use "
                f"compatible_K_grouped()"
            )
        ni = num // W
        if ni > K:
            raise ValueError(
                f"edge {i} would be assigned n_i={ni} > K={K} parts"
            )
        edge_parts.append(tuple((offset + t) % K for t in range(ni)))
        offset += ni
    assert offset == K * (gtol.s_e + 1)

    worker_local: List[Tuple[Tuple[int, ...], ...]] = []
    for i in range(topo.n):
        ni = len(edge_parts[i])
        mi = topo.m[i]
        num = ni * (gtol.s_w_vec[i] + 1)
        if num % mi != 0:
            raise ValueError(
                f"D_i for edge {i} not integral (n_i={ni}, m_i={mi}, "
                f"s_w^i={gtol.s_w_vec[i]}); use compatible_K_grouped()"
            )
        D_i = num // mi
        worker_local.append(tuple(
            tuple((j * D_i + t) % ni for t in range(D_i))
            for j in range(mi)
        ))

    asg = Assignment(
        topo=topo, tol=gtol, K=K,
        edge_parts=tuple(edge_parts),
        worker_local=tuple(worker_local),
    )
    # per-edge cover invariants (Assignment._check_covers assumes the
    # uniform tolerance, so verify the grouped covers here)
    cover = asg.parts_per_edge_cover()
    bad = {k: c for k, c in cover.items() if c != gtol.s_e + 1}
    if bad:
        raise AssertionError(f"edge cover != s_e+1: {bad}")
    for i in range(topo.n):
        want = gtol.s_w_vec[i] + 1
        bad = {l: c for l, c in asg.local_cover(i).items() if c != want}
        if bad:
            raise AssertionError(
                f"edge {i} local cover != s_w^i+1={want}: {bad}"
            )
    return asg


class GroupedHGCCode(HGCCode):
    """Two-layer code with per-edge worker tolerances.

    Same frozen-dataclass fields as :class:`HGCCode`; ``tol`` holds a
    :class:`GroupTolerance`.  Every decode method of the base class
    already resolves the worker tolerance through ``tol.s_w_of(i)``, so
    only construction and the (now per-edge) load accessors differ.
    """

    @staticmethod
    def build(
        topo: Topology,
        tol: GroupTolerance,
        K: Optional[int] = None,
        seed: int = 0,
        construction: str = "random",
    ) -> "GroupedHGCCode":
        if construction != "random":
            raise ValueError(
                "grouped codes support only the random construction "
                "(FRC divisibility is a uniform-tolerance property)"
            )
        tol.validate(topo)
        if K is None:
            K = compatible_K_grouped(
                topo, tol, at_least=topo.total_workers
            )
        asg = build_grouped_assignment(topo, tol, K)
        b_supports = tuple(
            tuple(sorted(set(p))) for p in asg.edge_parts
        )
        if tol.s_e == 0:
            B = build_replication_code(b_supports, K)
        else:
            B = build_random_code(b_supports, K, tol.s_e, seed=seed)
        dbars: List[LinearCode] = []
        for i in range(topo.n):
            ni = asg.n_i(i)
            sup = tuple(
                tuple(sorted(set(w))) for w in asg.worker_local[i]
            )
            if tol.s_w_vec[i] == 0:
                dbars.append(build_replication_code(sup, ni))
            else:
                dbars.append(build_random_code(
                    sup, ni, tol.s_w_vec[i], seed=seed + 1 + i
                ))
        return GroupedHGCCode(
            topo=topo, tol=tol, K=K, assignment=asg, B=B,
            Dbar=tuple(dbars), construction="random",
        )

    @property
    def loads(self) -> Tuple[int, ...]:
        """Per-edge worker load D_i."""
        return tuple(
            len(self.assignment.worker_local[i][0])
            for i in range(self.topo.n)
        )

    @property
    def load(self) -> int:
        """Bottleneck per-worker load max_i D_i (scalar summary)."""
        return max(self.loads)

    @property
    def load_array(self) -> np.ndarray:
        """Flat per-worker loads in ``topo.worker_ids()`` order."""
        return np.repeat(
            np.asarray(self.loads, np.float64), np.asarray(self.topo.m)
        )


# ----------------------------------------------------------------------
# the grouped planner core (heterogeneity-aware JNCSS generalization)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GroupedPlanResult:
    s_e: int
    s_w_vec: Tuple[int, ...]
    T_tol: float
    # model (fractional) per-edge loads at the requested K
    D_vec: Tuple[float, ...]


def plan_grouped(
    params: ClusterParams,
    K: int,
    only_compatible: bool = False,
) -> GroupedPlanResult:
    """Jointly pick ``(s_e, s_w^1..s_w^n)`` minimizing expected time.

    Because D_i = K(s_e+1)(s_w^i+1)/W depends only on edge i's own
    tolerance, the inner problem decouples: per edge, pick the s_w^i
    minimizing A_i + (m_i−s_w^i)-th min of B_(i,j)(D_i); the system time
    is then the (n−s_e)-th min over the per-edge optima, and the outer
    s_e grid is JNCSS's.  ``only_compatible=True`` restricts the search
    to tolerances whose construction is integral at exactly this K
    (the scheme factory's fixed-K mode).
    """
    topo = params.topo
    W = topo.total_workers
    A = params.expected_edge_upload()
    best = None
    for s_e in range(topo.n):
        if not tradeoff.feasible(topo, Tolerance(s_e, 0)):
            continue
        if only_compatible and any(
            (K * (s_e + 1) * mi) % W != 0 for mi in topo.m
        ):
            continue
        s_w_vec: List[int] = []
        edge_T = np.empty(topo.n)
        D_vec: List[float] = []
        off = 0
        infeasible = False
        for i in range(topo.n):
            mi = topo.m[i]
            best_i = None
            for s_w in range(mi):
                D = K * (s_e + 1) * (s_w + 1) / W
                if only_compatible:
                    ni = K * (s_e + 1) * mi // W
                    if (ni * (s_w + 1)) % mi != 0:
                        continue
                B = params.expected_worker_total(D)[off : off + mi]
                T_i = A[i] + kth_min(B, mi - s_w)
                if best_i is None or T_i < best_i[0]:
                    best_i = (float(T_i), s_w, D)
            if best_i is None:
                infeasible = True
                break
            edge_T[i] = best_i[0]
            s_w_vec.append(best_i[1])
            D_vec.append(best_i[2])
            off += mi
        if infeasible:
            continue
        T = float(kth_min(edge_T, topo.n - s_e))
        if best is None or T < best[0]:
            best = (T, s_e, tuple(s_w_vec), tuple(D_vec))
    if best is None:
        raise ValueError(
            f"no feasible grouped tolerance for topology {topo.m} "
            f"at K={K}"
        )
    T, s_e, s_w_vec, D_vec = best
    return GroupedPlanResult(
        s_e=s_e, s_w_vec=s_w_vec, T_tol=T, D_vec=D_vec
    )


def price_grouped(
    params: ClusterParams,
    gtol: GroupTolerance,
    loads: Sequence[float],
) -> float:
    """Expected iteration time T̂ (ms) of a grouped code at its per-edge
    deployed loads — the grouped counterpart of
    :func:`repro.dist.elastic.price_tolerance`."""
    topo = params.topo
    D_flat = np.repeat(
        np.asarray(loads, np.float64), np.asarray(topo.m)
    )
    B = params.expected_worker_total(D_flat)
    A = params.expected_edge_upload()
    scores = np.empty(topo.n)
    off = 0
    for i in range(topo.n):
        mi = topo.m[i]
        scores[i] = A[i] + kth_min(
            B[off : off + mi], mi - gtol.s_w_vec[i]
        )
        off += mi
    return float(kth_min(scores, topo.n - gtol.s_e))
