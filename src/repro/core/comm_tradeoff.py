"""Communication–computation trade-off — Gholami et al. (arXiv:2502.18251)
flavor, restricted to the exact-decode HGC family.

Gholami et al. study hierarchical gradient coding under a *communication
budget*: the master may ingest fewer than ``n − s_e`` messages per
iteration if workers compute (and edges forward) more redundancy.  Their
dimension-reduction construction trades exactness for bandwidth, which
would break this repo's scalar-λ ``collapsed_weights`` seam — so here we
keep the exact HGC family and expose the same trade-off axis through
tolerance selection:

  * master ingests ``n − s_e`` edge messages,
  * edge ``i`` ingests ``m_i − s_w`` worker messages,
  * per-worker load is ``D = K (s_e+1)(s_w+1) / Σ m_i``.

Shrinking the message budgets forces the tolerances UP, which forces the
per-worker computation UP — the communication↔computation trade-off,
navigated by :func:`solve_comm_budget` and charted by
:func:`tradeoff_curve`.  Every point decodes exactly through the
unchanged two-stage λ pipeline, so replans stay zero-recompile.

:func:`pareto_front` is the generic non-dominated filter used by
``benchmarks/bench_pareto.py`` (all axes minimized; negate an axis to
maximize it).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import jncss, tradeoff
from repro.core.runtime_model import ClusterParams, kth_min
from repro.core.topology import Tolerance


@dataclasses.dataclass(frozen=True)
class CommPoint:
    """One (tolerance → communication/computation) operating point."""

    s_e: int
    s_w: int
    D: float  # model per-worker load, eq (44)
    master_msgs: int  # edge→master messages ingested per iteration
    edge_msgs: int  # worst-case worker→edge messages at one edge
    T_hat: float  # expected iteration time at this point (ms)

    @property
    def tol(self) -> Tolerance:
        return Tolerance(self.s_e, self.s_w)


def enumerate_points(params: ClusterParams, K: int) -> List[CommPoint]:
    """All feasible (s_e, s_w) operating points with their comm/comp
    coordinates, in grid order."""
    topo = params.topo
    out: List[CommPoint] = []
    max_m = max(topo.m)
    for s_e in range(topo.n):
        for s_w in range(topo.m_min):
            tol = Tolerance(s_e, s_w)
            if not tradeoff.feasible(topo, tol):
                continue
            D = jncss.load_D(topo, K, s_e, s_w)
            scores, _ = jncss._edge_scores(params, D, s_w)
            out.append(CommPoint(
                s_e=s_e,
                s_w=s_w,
                D=D,
                master_msgs=topo.n - s_e,
                edge_msgs=max_m - s_w,
                T_hat=float(kth_min(scores, topo.n - s_e)),
            ))
    if not out:
        raise ValueError(f"no feasible tolerance for topology {topo.m}")
    return out


def _integral_at(topo, s_e: int, s_w: int, K: int) -> bool:
    """True iff the cyclic construction is integral at exactly this K."""
    W = topo.total_workers
    for mi in topo.m:
        num = K * (s_e + 1) * mi
        if num % W != 0:
            return False
        if ((num // W) * (s_w + 1)) % mi != 0:
            return False
    return True


def solve_comm_budget(
    params: ClusterParams,
    K: int,
    max_master_msgs: Optional[int] = None,
    max_edge_msgs: Optional[int] = None,
    integral_K: Optional[int] = None,
) -> CommPoint:
    """Cheapest exact point within the message budgets.

    Among feasible points with ``master_msgs ≤ max_master_msgs`` and
    ``edge_msgs ≤ max_edge_msgs`` (None = unconstrained), pick the one
    with minimal per-worker load D, breaking ties on expected time T̂
    (two points can share D — e.g. (s_e,s_w)=(1,0) and (0,1) — and then
    the cluster shape decides which is faster).  ``integral_K`` further
    restricts to tolerances whose construction is integral at that K
    (the scheme factory's fixed-K mode; planners instead adjust K after
    picking the tolerance).
    """
    pts = enumerate_points(params, K)
    ok = [
        p for p in pts
        if (max_master_msgs is None or p.master_msgs <= max_master_msgs)
        and (max_edge_msgs is None or p.edge_msgs <= max_edge_msgs)
        and (integral_K is None
             or _integral_at(params.topo, p.s_e, p.s_w, integral_K))
    ]
    if not ok:
        raise ValueError(
            f"no feasible tolerance within the message budgets "
            f"(master ≤ {max_master_msgs}, edge ≤ {max_edge_msgs}) for "
            f"topology {params.topo.m}"
        )
    return min(ok, key=lambda p: (p.D, p.T_hat))


def tradeoff_curve(params: ClusterParams, K: int) -> List[CommPoint]:
    """The communication→computation frontier: for each master message
    budget b = 1..n, the min-load point achievable within it (dropping
    budgets where relaxing buys nothing new)."""
    topo = params.topo
    out: List[CommPoint] = []
    for budget in range(1, topo.n + 1):
        try:
            p = solve_comm_budget(params, K, max_master_msgs=budget)
        except ValueError:
            continue
        if not out or p != out[-1]:
            out.append(p)
    return out


def pareto_front(rows: Sequence[Sequence[float]]) -> np.ndarray:
    """Boolean mask of non-dominated rows (every axis minimized).

    Row a dominates row b iff a ≤ b on all axes and a < b on at least
    one.  Duplicated rows are all kept (neither strictly dominates).
    Callers maximizing an axis should negate it first.
    """
    pts = np.asarray(rows, dtype=np.float64)
    if pts.ndim != 2:
        raise ValueError(f"expected 2-D rows, got shape {pts.shape}")
    n = pts.shape[0]
    keep = np.ones(n, dtype=bool)
    for i in range(n):
        if not keep[i]:
            continue
        le = np.all(pts <= pts[i], axis=1)
        lt = np.any(pts < pts[i], axis=1)
        if np.any(le & lt):
            keep[i] = False
    return keep
