"""The seven comparison schemes of paper §V-A.

Every scheme exposes the same protocol, consumed by the simulator
(`repro.sim`), the benchmarks and the distributed launcher:

  * ``load``               — per-worker computational load D,
  * ``iteration(sample)``  — iteration time + the (edges, workers) that
                             were actually waited for, per the scheme's
                             waiting rule (eqs 31–33),
  * ``gradient(g_parts, fast)`` — the aggregated gradient the master
                             obtains (exact for all coded schemes and
                             Uncoded; partial for Greedy),
  * ``master_messages``    — communication load of the master (Fig. 7).

Equivalences used (and verified in tests):
  CGC-W  ≡ HGC(s_e = 0, s_w)   (code workers↔edge, master waits all edges)
  CGC-E  ≡ HGC(s_e, s_w = 0)   (workers uncoded, code edges↔master)
Standard GC is a flat worker↔master code with equal tolerance
  s = max_{|S_e|=s_e} Σ_{i∈S_e} m_i + (n−s_e)·s_w   (eq 8),
workers communicating directly with the master (no edge hop).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import jncss as jncss_mod
from repro.core import tradeoff
from repro.core.encoding import LinearCode, build_random_code, cyclic_supports
from repro.core.hgc import HGCCode
from repro.core.runtime_model import ClusterParams, kth_min
from repro.core.topology import Tolerance, Topology

SCHEME_NAMES = (
    "uncoded",
    "greedy",
    "cgc_w",
    "cgc_e",
    "standard_gc",
    "hgc",
    "hgc_jncss",
    "hgc_grouped",
    "hgc_comm",
)


@dataclasses.dataclass
class IterationOutcome:
    time: float
    fast_edges: Tuple[int, ...]
    # per-edge tuple of worker indices waited for ((), if edge unused)
    fast_workers: Tuple[Tuple[int, ...], ...]


class Scheme:
    """Base protocol; see module docstring."""

    name: str
    topo: Topology
    K: int
    exact: bool = True

    @property
    def load(self) -> float:
        raise NotImplementedError

    def iteration(
        self, sample: Tuple[np.ndarray, np.ndarray, np.ndarray]
    ) -> IterationOutcome:
        raise NotImplementedError

    def gradient(
        self, g_parts: np.ndarray, outcome: IterationOutcome
    ) -> np.ndarray:
        raise NotImplementedError

    @property
    def master_messages(self) -> int:
        raise NotImplementedError


def _hier_iteration(
    topo: Topology,
    sample: Tuple[np.ndarray, np.ndarray, np.ndarray],
    s_e: int,
    s_w,
) -> IterationOutcome:
    """eqs (32)/(33): wait fastest m_i−s_w workers, then fastest n−s_e edges.

    ``s_w`` may be a scalar (uniform) or a per-edge vector (grouped
    tolerance — each edge waits at its own s_w^i).
    """
    wt, eu, _ = sample
    n = topo.n
    s_w_arr = np.asarray(s_w)
    if s_w_arr.ndim == 0:
        s_w_arr = np.full(n, int(s_w_arr))
    edge_T = np.empty(n)
    fast_w: List[Tuple[int, ...]] = []
    off = 0
    for i in range(n):
        mi = topo.m[i]
        wi = wt[off : off + mi]
        k = mi - int(s_w_arr[i])
        order = np.argsort(wi, kind="stable")[:k]
        edge_T[i] = eu[i] + wi[order[-1]]
        fast_w.append(tuple(sorted(order.tolist())))
        off += mi
    k_e = n - s_e
    eorder = np.argsort(edge_T, kind="stable")[:k_e]
    T = float(edge_T[eorder[-1]])
    chosen = set(eorder.tolist())
    fast_workers = tuple(
        fast_w[i] if i in chosen else () for i in range(n)
    )
    return IterationOutcome(
        time=T,
        fast_edges=tuple(sorted(eorder.tolist())),
        fast_workers=fast_workers,
    )


def _round_robin_parts(topo: Topology, K: int) -> List[List[Tuple[int, ...]]]:
    """Disjoint near-equal split of K parts over all workers (D≈K/W)."""
    W = topo.total_workers
    flat: List[List[int]] = [[] for _ in range(W)]
    for k in range(K):
        flat[k % W].append(k)
    out: List[List[Tuple[int, ...]]] = []
    w = 0
    for i in range(topo.n):
        row = []
        for _j in range(topo.m[i]):
            row.append(tuple(flat[w]))
            w += 1
        out.append(row)
    return out


class UncodedScheme(Scheme):
    """D = K/W disjoint parts each; everyone waits for everyone."""

    name = "uncoded"

    def __init__(self, topo: Topology, K: int):
        self.topo, self.K = topo, K
        self.parts = _round_robin_parts(topo, K)

    @property
    def load(self) -> float:
        return self.K / self.topo.total_workers

    def iteration(self, sample) -> IterationOutcome:
        return _hier_iteration(self.topo, sample, s_e=0, s_w=0)

    def gradient(self, g_parts, outcome) -> np.ndarray:
        return g_parts.sum(axis=0)

    @property
    def master_messages(self) -> int:
        return self.topo.n


class GreedyScheme(Scheme):
    """Uncoded placement, coded-style waiting: stragglers are *dropped*.

    The aggregate misses the dropped parts (rescaled to full-batch
    magnitude) — unbiased only under IID parts, which is exactly the
    paper's point about non-IID degradation.
    """

    name = "greedy"
    exact = False

    def __init__(self, topo: Topology, K: int, s_e: int, s_w: int):
        Tolerance(s_e, s_w).validate(topo)
        self.topo, self.K, self.s_e, self.s_w = topo, K, s_e, s_w
        self.parts = _round_robin_parts(topo, K)

    @property
    def load(self) -> float:
        return self.K / self.topo.total_workers

    def iteration(self, sample) -> IterationOutcome:
        return _hier_iteration(self.topo, sample, self.s_e, self.s_w)

    def gradient(self, g_parts, outcome) -> np.ndarray:
        got: List[int] = []
        for i in outcome.fast_edges:
            for j in outcome.fast_workers[i]:
                got.extend(self.parts[i][j])
        got = sorted(set(got))
        if not got:
            return np.zeros_like(g_parts[0])
        return g_parts[got].sum(axis=0) * (self.K / len(got))

    @property
    def master_messages(self) -> int:
        return self.topo.n - self.s_e


class HGCScheme(Scheme):
    """The paper's scheme (§III) at tolerance (s_e, s_w)."""

    name = "hgc"

    def __init__(
        self,
        topo: Topology,
        K: int,
        s_e: int,
        s_w: int,
        seed: int = 0,
        construction: str = "random",
        name: Optional[str] = None,
    ):
        self.topo, self.K = topo, K
        self.code = HGCCode.build(
            topo, Tolerance(s_e, s_w), K=K, seed=seed,
            construction=construction,
        )
        self.s_e, self.s_w = s_e, s_w
        if name:
            self.name = name

    @property
    def load(self) -> float:
        return float(self.code.load)

    def iteration(self, sample) -> IterationOutcome:
        return _hier_iteration(self.topo, sample, self.s_e, self.s_w)

    def gradient(self, g_parts, outcome) -> np.ndarray:
        lam = self.code.collapsed_weights(
            outcome.fast_edges, outcome.fast_workers
        )
        out = np.zeros_like(g_parts[0], dtype=np.float64)
        for i in outcome.fast_edges:
            for j in outcome.fast_workers[i]:
                w = lam[self.topo.flat_index(i, j)]
                out += w * self.code.worker_encode(i, j, g_parts)
        return out

    @property
    def master_messages(self) -> int:
        return self.topo.n - self.s_e


class GroupedHGCScheme(HGCScheme):
    """Heterogeneity-aware grouped HGC (per-edge worker tolerances).

    Wraps :class:`repro.core.grouping.GroupedHGCCode`; the waiting rule
    applies edge ``i``'s own ``s_w^i``, so on intra-edge-heterogeneous
    clusters the planner can buy tolerance only where it pays.
    """

    def __init__(
        self,
        topo: Topology,
        K: int,
        s_e: int,
        s_w_vec: Sequence[int],
        seed: int = 0,
    ):
        from repro.core.grouping import GroupedHGCCode, GroupTolerance

        self.topo, self.K = topo, K
        gtol = GroupTolerance(s_e, tuple(int(s) for s in s_w_vec))
        self.code = GroupedHGCCode.build(topo, gtol, K=K, seed=seed)
        # self.s_w is the vector: the inherited iteration() passes it to
        # _hier_iteration, which applies it per edge.
        self.s_e, self.s_w = s_e, tuple(gtol.s_w_vec)
        self.name = "hgc_grouped"

    @property
    def load(self) -> float:
        """Bottleneck (max over edges) per-worker load."""
        return float(self.code.load)

    @property
    def load_array(self) -> np.ndarray:
        """Flat per-worker loads (edges may differ)."""
        return self.code.load_array


class CGCWScheme(HGCScheme):
    """Conventional single-layer coding workers↔edges (≡ HGC(0, s_w))."""

    def __init__(self, topo, K, s_w, seed: int = 0):
        super().__init__(topo, K, s_e=0, s_w=s_w, seed=seed, name="cgc_w")

    @property
    def master_messages(self) -> int:
        return self.topo.n


class CGCEScheme(HGCScheme):
    """Conventional single-layer coding edges↔master (≡ HGC(s_e, 0))."""

    def __init__(self, topo, K, s_e, seed: int = 0):
        super().__init__(topo, K, s_e=s_e, s_w=0, seed=seed, name="cgc_e")


class StandardGCScheme(Scheme):
    """Flat worker↔master gradient coding, no edge layer (paper §V-A).

    Equal tolerance rule: s = max_{|S_e|=s_e} Σ m_i + (n−s_e)·s_w.
    """

    name = "standard_gc"

    def __init__(self, topo: Topology, K: int, s_e: int, s_w: int,
                 seed: int = 0):
        self.topo, self.K = topo, K
        worst_edges = sum(sorted(topo.m, reverse=True)[:s_e])
        self.s = worst_edges + (topo.n - s_e) * s_w
        W = topo.total_workers
        if self.s >= W:
            raise ValueError(f"equal tolerance s={self.s} ≥ W={W}")
        if (K * (self.s + 1)) % W != 0:
            raise ValueError(
                f"K={K} incompatible with flat code: W={W}, s={self.s}"
            )
        D = K * (self.s + 1) // W
        sup = cyclic_supports(K, [D] * W)
        self.flat_code = build_random_code(sup, K, self.s, seed=seed)
        self._D = D

    @property
    def load(self) -> float:
        return float(self._D)

    def iteration(self, sample) -> IterationOutcome:
        _, _, wd = sample
        W = self.topo.total_workers
        k = W - self.s
        order = np.argsort(wd, kind="stable")[:k]
        T = float(wd[order[-1]])
        fast = set(order.tolist())
        fast_workers = []
        w = 0
        for i in range(self.topo.n):
            row = []
            for j in range(self.topo.m[i]):
                if w in fast:
                    row.append(j)
                w += 1
            fast_workers.append(tuple(row))
        return IterationOutcome(
            time=T,
            fast_edges=tuple(range(self.topo.n)),
            fast_workers=tuple(fast_workers),
        )

    def gradient(self, g_parts, outcome) -> np.ndarray:
        rows = [
            self.topo.flat_index(i, j)
            for i in outcome.fast_edges
            for j in outcome.fast_workers[i]
        ]
        rows = sorted(rows)[: self.topo.total_workers - self.s]
        a = self.flat_code.full_decode_weights(rows)
        return (a @ self.flat_code.matrix) @ g_parts

    @property
    def master_messages(self) -> int:
        return self.topo.total_workers - self.s


def make_scheme(
    name: str,
    topo: Topology,
    K: int,
    s_e: int = 1,
    s_w: int = 1,
    params: Optional[ClusterParams] = None,
    seed: int = 0,
    construction: str = "random",
    master_budget: Optional[int] = None,
    edge_budget: Optional[int] = None,
) -> Scheme:
    """Factory over SCHEME_NAMES.

    ``hgc_jncss``, ``hgc_grouped`` and ``hgc_comm`` require ``params``
    (they plan from the cluster model).  For ``hgc_comm`` the message
    budgets default to ``n − s_e`` (master) and ``max_i m_i − s_w``
    (edge); pass ``master_budget``/``edge_budget`` to set them directly.
    """
    name = name.lower()
    if name == "uncoded":
        return UncodedScheme(topo, K)
    if name == "greedy":
        return GreedyScheme(topo, K, s_e, s_w)
    if name == "cgc_w":
        return CGCWScheme(topo, K, s_w, seed=seed)
    if name == "cgc_e":
        return CGCEScheme(topo, K, s_e, seed=seed)
    if name == "standard_gc":
        return StandardGCScheme(topo, K, s_e, s_w, seed=seed)
    if name == "hgc":
        return HGCScheme(
            topo, K, s_e, s_w, seed=seed, construction=construction
        )
    if name == "hgc_jncss":
        if params is None:
            raise ValueError("hgc_jncss needs ClusterParams for Algorithm 2")
        res = jncss_mod.solve(params, K)
        sch = HGCScheme(
            topo, K, res.s_e, res.s_w, seed=seed, construction=construction,
            name="hgc_jncss",
        )
        sch.jncss_result = res  # attach for reporting
        return sch
    if name == "hgc_grouped":
        if params is None:
            raise ValueError(
                "hgc_grouped needs ClusterParams for the grouped planner"
            )
        from repro.core import grouping

        res = grouping.plan_grouped(params, K, only_compatible=True)
        sch = GroupedHGCScheme(topo, K, res.s_e, res.s_w_vec, seed=seed)
        sch.grouped_result = res  # attach for reporting
        return sch
    if name == "hgc_comm":
        if params is None:
            raise ValueError(
                "hgc_comm needs ClusterParams for the budget solver"
            )
        from repro.core import comm_tradeoff

        if master_budget is None:
            master_budget = topo.n - s_e
        if edge_budget is None:
            edge_budget = max(topo.m) - s_w
        point = comm_tradeoff.solve_comm_budget(
            params, K, max_master_msgs=master_budget,
            max_edge_msgs=edge_budget, integral_K=K,
        )
        sch = HGCScheme(
            topo, K, point.s_e, point.s_w, seed=seed,
            construction=construction, name="hgc_comm",
        )
        sch.comm_point = point  # attach for reporting
        return sch
    raise ValueError(f"unknown scheme {name!r}; choose from {SCHEME_NAMES}")
