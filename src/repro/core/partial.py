"""Partial-result / multi-message gradient coding (beyond-paper).

The paper (§III end) notes that "accelerative single-layer gradient
coding techniques like utilizing partial computing results [18]
(Ozfatura et al.) can also be combined in coding between workers and
edge nodes".  This module implements that combination: each worker
sends a message after EVERY part it finishes (in its assignment order)
instead of one message at the end.  The edge can then decode as soon as
any prefix-pattern covering its part-set arrives — strictly earlier in
expectation than waiting for the fastest f_w full results.

Message t of worker (i,j) is the coded combination of its first t
parts; the edge solves, over the received prefix lengths {t_j}, for
weights c_{j,t} with  Σ_j Σ_t c_{j,t}·M_{j,t} = b_i  restricted to the
edge's parts — a small least-squares per iteration, same machinery as
eq. (24) with an enlarged (Σ t_j) × n_i system.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hgc import HGCCode

_RTOL = 1e-8


def worker_prefix_messages(
    code: HGCCode, i: int, j: int, g_parts: np.ndarray
) -> np.ndarray:
    """(D, dim): message t = coded combo of the worker's first t parts."""
    coeff = code.worker_coeffs(i, j)  # (K,)
    order = code.assignment.worker_parts(i, j)
    msgs = []
    acc = np.zeros_like(g_parts[0])
    for t, k in enumerate(order):
        acc = acc + coeff[k] * g_parts[k]
        msgs.append(acc.copy())
    return np.stack(msgs)


def prefix_coeff_matrix(code: HGCCode, i: int) -> np.ndarray:
    """(m_i·D, K): coefficient rows of every prefix message of edge i."""
    rows = []
    for j in range(code.topo.m[i]):
        coeff = code.worker_coeffs(i, j)
        order = code.assignment.worker_parts(i, j)
        acc = np.zeros(code.K)
        for k in order:
            acc = acc.copy()
            acc[k] += coeff[k]
            rows.append(acc.copy())
    return np.stack(rows)


def edge_decode_from_prefixes(
    code: HGCCode,
    i: int,
    prefix_lengths: Sequence[int],  # parts finished per worker (0..D)
    messages: Dict[int, np.ndarray],  # worker j → (t_j, dim) prefixes
) -> Optional[np.ndarray]:
    """Decode G_i from partial results if the received system spans b_i.

    Returns None when the prefixes cannot yet span (need more results).
    """
    D = code.load
    M = prefix_coeff_matrix(code, i)  # (m_i·D, K)
    live_rows: List[int] = []
    stacked: List[np.ndarray] = []
    for j, t_j in enumerate(prefix_lengths):
        for t in range(t_j):
            live_rows.append(j * D + t)
            stacked.append(messages[j][t])
    if not live_rows:
        return None
    A = M[live_rows]  # (R, K)
    target = code.B.matrix[i]  # b_i
    sol, *_ = np.linalg.lstsq(A.T, target, rcond=None)
    if np.max(np.abs(sol @ A - target)) > _RTOL:
        return None
    out = np.zeros_like(stacked[0])
    for w, msg in zip(sol, stacked):
        out = out + w * msg
    return out


def earliest_decode_progress(
    code: HGCCode, i: int, arrival_order: Sequence[Tuple[int, int]]
) -> int:
    """How many prefix messages (in arrival order) until edge i decodes.

    ``arrival_order``: sequence of (worker j, prefix index t) events.
    Returns the 1-based count, or -1 if never decodable.
    Used by tests/benchmarks to show the speedup over full-result HGC.
    """
    D = code.load
    M = prefix_coeff_matrix(code, i)
    target = code.B.matrix[i]
    lens = [0] * code.topo.m[i]
    for n_arrived, (j, t) in enumerate(arrival_order, start=1):
        lens[j] = max(lens[j], t + 1)
        rows = [jj * D + tt for jj in range(code.topo.m[i])
                for tt in range(lens[jj])]
        A = M[rows]
        sol, *_ = np.linalg.lstsq(A.T, target, rcond=None)
        if np.max(np.abs(sol @ A - target)) <= _RTOL:
            return n_arrived
    return -1
