"""Theorem 1 / Corollary 1 / Corollary 2 — the computational trade-off.

All quantities follow paper §II-B.  ``D`` is the number of the ``K``
disjoint sub-datasets each worker processes ("computational load").
"""
from __future__ import annotations

import math
from fractions import Fraction
from typing import Sequence

from repro.core.topology import Tolerance, Topology


def min_load_fraction(topo: Topology, tol: Tolerance) -> Fraction:
    """Theorem 1 lower bound on D/K: (s_e+1)(s_w+1) / Σ_i m_i."""
    tol.validate(topo)
    return Fraction((tol.s_e + 1) * (tol.s_w + 1), topo.total_workers)


def min_load(topo: Topology, tol: Tolerance, K: int) -> int:
    """Smallest integer D satisfying Theorem 1 for a given K."""
    frac = min_load_fraction(topo, tol)
    return math.ceil(frac * K)


def achievable_load(topo: Topology, tol: Tolerance, K: int) -> int:
    """Load of the HGC construction, eq. (23): D = K(s_e+1)(s_w+1)/Σm_i.

    Raises if the construction's divisibility requirements fail (callers
    should pick K via :func:`compatible_K`).
    """
    tol.validate(topo)
    num = K * (tol.s_e + 1) * (tol.s_w + 1)
    den = topo.total_workers
    if num % den != 0:
        raise ValueError(
            f"K={K} incompatible: K(s_e+1)(s_w+1)={num} not divisible by "
            f"Σm_i={den}; use compatible_K()"
        )
    return num // den


def compatible_K(topo: Topology, tol: Tolerance, at_least: int = 1) -> int:
    """Smallest K ≥ at_least for which the HGC construction is integral.

    Requirements (paper eqs (15), (18)):
      * n_i = K(s_e+1) m_i / Σm_i integral for all i,
      * D   = n_i (s_w+1) / m_i  integral for all i (same D by construction).
    """
    tol.validate(topo)
    K = max(1, at_least)
    while True:
        if _construction_integral(topo, tol, K):
            return K
        K += 1


def _construction_integral(topo: Topology, tol: Tolerance, K: int) -> bool:
    tot = topo.total_workers
    for mi in topo.m:
        num_ni = K * (tol.s_e + 1) * mi
        if num_ni % tot != 0:
            return False
        ni = num_ni // tot
        if (ni * (tol.s_w + 1)) % mi != 0:
            return False
    return True


def feasible(topo: Topology, tol: Tolerance) -> bool:
    """Paper §II-B feasibility: Σ_{i∈F,|F|=f_e} m_i (s_e+1) / Σ m_i ≥ 1.

    Evaluated at the worst case F (the f_e edges with the *fewest*
    workers), which is the binding case.
    """
    tol.validate(topo)
    f_e = topo.n - tol.s_e
    worst = sum(sorted(topo.m)[:f_e])
    return worst * (tol.s_e + 1) >= topo.total_workers


def conventional_load_fraction(topo: Topology, tol: Tolerance) -> Fraction:
    """Corollary 1, eq. (9): load of single-layer coding at equal tolerance.

    A single-layer worker↔master code must tolerate
    s_max = max_{|S_e|=s_e} Σ_{i∈S_e} m_i + (n−s_e) s_w
    worker stragglers, hence D_con/K = (s_max + 1)/Σ m_i.
    """
    tol.validate(topo)
    worst_edges = sum(sorted(topo.m, reverse=True)[: tol.s_e])
    s_max = worst_edges + (topo.n - tol.s_e) * tol.s_w
    return Fraction(s_max + 1, topo.total_workers)


def hgc_vs_conventional_savings(topo: Topology, tol: Tolerance) -> Fraction:
    """Load ratio D_hgc / D_con  (<1 whenever s_e>0 or heterogeneous)."""
    return min_load_fraction(topo, tol) / conventional_load_fraction(topo, tol)


def multilayer_min_load_fraction(
    layer_stragglers: Sequence[int], total_workers: int
) -> Fraction:
    """Corollary 2: D/K ≥ Π_l (s_l + 1) / W for an L-layer tree."""
    if total_workers <= 0:
        raise ValueError("total_workers must be positive")
    num = 1
    for s in layer_stragglers:
        if s < 0:
            raise ValueError("straggler counts must be non-negative")
        num *= s + 1
    return Fraction(num, total_workers)
