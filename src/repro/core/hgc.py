"""Hierarchical Gradient Coding — paper §III, Algorithm 1.

``HGCCode`` materializes the full two-layer code:

  * layer 1: ``B ∈ R^{n×K}`` between master and edges (Condition 1),
  * layer 2: ``D̄^i ∈ R^{m_i×n_i}`` between edge ``E_i`` and its workers
    (Condition 2), expanded to ``D^i ∈ R^{m_i×K}`` per eq. (21).

Worker ``(i,j)`` transmits (eq. 22):

    G_ij = d^i_j · diag(g_1..g_K) · b_i^T = Σ_k d^i_jk b_ik g_k

so its *effective* per-part coefficient vector is ``d^i_j ⊙ b_i``.
Edge decode (eq. 25) folds ``c^i_F``; master decode (eq. 27) folds
``a_F``.  The fully-collapsed view used by the distributed runtime:

    g = Σ_{i∈F} a_i Σ_{j∈F_i} c^i_j G_ij = Σ_{(i,j)} λ_ij G_ij ,

with per-worker scalar weights ``λ_ij = a_i c^i_j`` that depend only on
the straggler pattern — so a tolerated node drop costs one host-side
linear solve and *zero* recompilation of the training step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import tradeoff
from repro.core.assignment import Assignment, build_assignment
from repro.core.encoding import (
    LinearCode,
    build_frc_code,
    build_random_code,
    build_replication_code,
    cyclic_supports,
    frc_decode_weights,
)
from repro.core.topology import Tolerance, Topology


@dataclasses.dataclass(frozen=True)
class HGCCode:
    """The two-layer hierarchical gradient code of Algorithm 1."""

    topo: Topology
    tol: Tolerance
    K: int
    assignment: Assignment
    B: LinearCode  # n × K, layer-1 (master↔edges)
    Dbar: Tuple[LinearCode, ...]  # per-edge m_i × n_i, layer-2
    construction: str = "random"

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    @staticmethod
    def build(
        topo: Topology,
        tol: Tolerance,
        K: Optional[int] = None,
        seed: int = 0,
        construction: str = "random",
    ) -> "HGCCode":
        """Build the code; picks a compatible K automatically if omitted."""
        tol.validate(topo)
        if K is None:
            K = tradeoff.compatible_K(topo, tol, at_least=topo.total_workers)

        if construction == "frc":
            return HGCCode._build_frc(topo, tol, K)

        asg = build_assignment(topo, tol, K)
        # Layer 1: supports are exactly the edge part-sets (eq. 16).
        b_supports = tuple(tuple(sorted(set(p))) for p in asg.edge_parts)
        if tol.s_e == 0:
            # s_e=0 ⇒ each part on exactly one edge ⇒ replication code.
            B = build_replication_code(b_supports, K)
        else:
            B = build_random_code(b_supports, K, tol.s_e, seed=seed)

        dbars: List[LinearCode] = []
        for i in range(topo.n):
            ni = asg.n_i(i)
            sup = tuple(tuple(sorted(set(w))) for w in asg.worker_local[i])
            if tol.s_w == 0:
                dbars.append(build_replication_code(sup, ni))
            else:
                dbars.append(
                    build_random_code(sup, ni, tol.s_w, seed=seed + 1 + i)
                )
        return HGCCode(
            topo=topo,
            tol=tol,
            K=K,
            assignment=asg,
            B=B,
            Dbar=tuple(dbars),
            construction=construction,
        )

    @staticmethod
    def _build_frc(topo: Topology, tol: Tolerance, K: int) -> "HGCCode":
        """Fractional-repetition construction (beyond-paper conditioning).

        Requires (s_e+1) | n, (n/(s_e+1)) | K, and per edge
        (s_w+1) | m_i with (m_i/(s_w+1)) | n_i.  The data placement is
        *defined by* the FRC supports (group-partition, not cyclic).
        """
        from repro.core.assignment import assignment_from_supports

        if tol.s_e == 0:
            sup = cyclic_supports(
                K, [K // topo.n] * topo.n
            )  # s_e=0: disjoint cover needs n | K
            if K % topo.n != 0:
                raise ValueError("frc with s_e=0 requires n | K")
            B = build_replication_code(sup, K)
        else:
            if not _frc_ok(topo.n, K, tol.s_e):
                raise ValueError(
                    f"frc layer-1 divisibility fails: n={topo.n}, K={K}, "
                    f"s_e={tol.s_e}"
                )
            B = build_frc_code(topo.n, K, tol.s_e)
        edge_supports = B.supports
        dbars: List[LinearCode] = []
        worker_supports = []
        for i in range(topo.n):
            ni = len(edge_supports[i])
            mi = topo.m[i]
            if tol.s_w == 0:
                if ni % mi != 0:
                    raise ValueError(f"frc s_w=0 requires m_i | n_i (edge {i})")
                sup = cyclic_supports(ni, [ni // mi] * mi)
                dbars.append(build_replication_code(sup, ni))
            else:
                if not _frc_ok(mi, ni, tol.s_w):
                    raise ValueError(
                        f"frc layer-2 divisibility fails at edge {i}: "
                        f"m_i={mi}, n_i={ni}, s_w={tol.s_w}"
                    )
                dbars.append(build_frc_code(mi, ni, tol.s_w))
            worker_supports.append(dbars[-1].supports)
        asg = assignment_from_supports(
            topo, tol, K, edge_supports, tuple(worker_supports)
        )
        return HGCCode(
            topo=topo,
            tol=tol,
            K=K,
            assignment=asg,
            B=B,
            Dbar=tuple(dbars),
            construction="frc",
        )

    # ------------------------------------------------------------------
    # Derived matrices
    # ------------------------------------------------------------------
    def D_expanded(self, i: int) -> np.ndarray:
        """``D^i ∈ R^{m_i×K}`` — eq. (21): D̄^i scattered onto global ids."""
        ni = self.assignment.n_i(i)
        out = np.zeros((self.topo.m[i], self.K), dtype=np.float64)
        ep = self.assignment.edge_parts[i]
        for local in range(ni):
            out[:, ep[local]] += self.Dbar[i].matrix[:, local]
        return out

    def worker_coeffs(self, i: int, j: int) -> np.ndarray:
        """Effective per-part coefficients of worker (i,j): d^i_j ⊙ b_i."""
        return self.D_expanded(i)[j] * self.B.matrix[i]

    @property
    def load(self) -> int:
        """Per-worker computational load D (meets Theorem 1 w/ equality)."""
        return self.assignment.D

    # ------------------------------------------------------------------
    # Encoding / decoding (numpy reference semantics)
    # ------------------------------------------------------------------
    def worker_encode(self, i: int, j: int, g_parts: np.ndarray) -> np.ndarray:
        """``G_ij`` from stacked per-part gradients ``g_parts (K, dim)``."""
        return self.worker_coeffs(i, j) @ g_parts

    def edge_decode_weights(
        self, i: int, fast_workers: Sequence[int]
    ) -> np.ndarray:
        """``c^i_F`` (len m_i, zero on stragglers) — eq. (24)."""
        s_w_i = self.tol.s_w_of(i)
        if len(set(fast_workers)) < self.topo.m[i] - s_w_i:
            raise ValueError(
                f"edge {i}: need ≥ {self.topo.m[i] - s_w_i} fast "
                f"workers, got {len(set(fast_workers))}"
            )
        code = self.Dbar[i]
        if self.construction == "frc" and self.tol.s_w > 0 and _frc_ok(
            self.topo.m[i], self.assignment.n_i(i), self.tol.s_w
        ):
            return frc_decode_weights(code, fast_workers)
        return code.full_decode_weights(fast_workers)

    def master_decode_weights(self, fast_edges: Sequence[int]) -> np.ndarray:
        """``a_F`` (len n, zero on stragglers) — eq. (26)."""
        if len(set(fast_edges)) < self.topo.n - self.tol.s_e:
            raise ValueError(
                f"need ≥ {self.topo.n - self.tol.s_e} fast edges, got "
                f"{len(set(fast_edges))}"
            )
        if self.construction == "frc" and self.tol.s_e > 0 and _frc_ok(
            self.topo.n, self.K, self.tol.s_e
        ):
            return frc_decode_weights(self.B, fast_edges)
        return self.B.full_decode_weights(fast_edges)

    def edge_decode(
        self,
        i: int,
        fast_workers: Sequence[int],
        messages: Dict[int, np.ndarray],
    ) -> np.ndarray:
        """``G_i`` from the fastest workers' messages — eq. (25)."""
        c = self.edge_decode_weights(i, fast_workers)
        out = None
        for j in fast_workers:
            term = c[j] * messages[j]
            out = term if out is None else out + term
        return out

    def master_decode(
        self, fast_edges: Sequence[int], edge_results: Dict[int, np.ndarray]
    ) -> np.ndarray:
        """Full gradient ``g`` from the fastest edges — eq. (27)."""
        a = self.master_decode_weights(fast_edges)
        out = None
        for i in fast_edges:
            term = a[i] * edge_results[i]
            out = term if out is None else out + term
        return out

    # ------------------------------------------------------------------
    # Collapsed per-worker weights for the distributed runtime
    # ------------------------------------------------------------------
    def collapsed_weights(
        self,
        fast_edges: Sequence[int],
        fast_workers: Sequence[Sequence[int]],
    ) -> np.ndarray:
        """λ_ij = a_i c^i_j for every worker, zero for stragglers.

        Returns a flat array over ``topo.worker_ids()`` order.  The
        decoded full gradient equals Σ_ij λ_ij G_ij.
        """
        a = self.master_decode_weights(fast_edges)
        lam = np.zeros(self.topo.total_workers, dtype=np.float64)
        for i in fast_edges:
            c = self.edge_decode_weights(i, fast_workers[i])
            for j in fast_workers[i]:
                lam[self.topo.flat_index(i, j)] = a[i] * c[j]
        return lam

    def encoding_matrix_flat(self) -> np.ndarray:
        """(Σ m_i) × K matrix of effective worker coefficients."""
        rows = []
        for i in range(self.topo.n):
            Di = self.D_expanded(i)
            for j in range(self.topo.m[i]):
                rows.append(Di[j] * self.B.matrix[i])
        return np.stack(rows, axis=0)

    # ------------------------------------------------------------------
    # End-to-end simulation (reference pipeline used by tests/benches)
    # ------------------------------------------------------------------
    def simulate_iteration(
        self,
        g_parts: np.ndarray,
        edge_stragglers: Sequence[int] = (),
        worker_stragglers: Optional[Sequence[Sequence[int]]] = None,
    ) -> np.ndarray:
        """Run encode → edge decode → master decode; returns decoded g.

        ``g_parts``: (K, dim) stacked per-part gradients.
        """
        if worker_stragglers is None:
            worker_stragglers = [()] * self.topo.n
        fast_edges = [
            i for i in range(self.topo.n) if i not in set(edge_stragglers)
        ][: self.topo.n - self.tol.s_e]
        edge_results: Dict[int, np.ndarray] = {}
        for i in fast_edges:
            dead = set(worker_stragglers[i])
            fast = [j for j in range(self.topo.m[i]) if j not in dead]
            fast = fast[: self.topo.m[i] - self.tol.s_w_of(i)]
            msgs = {j: self.worker_encode(i, j, g_parts) for j in fast}
            edge_results[i] = self.edge_decode(i, fast, msgs)
        return self.master_decode(fast_edges, edge_results)


def _frc_ok(rows: int, cols: int, s: int) -> bool:
    return rows % (s + 1) == 0 and cols % (rows // (s + 1)) == 0
