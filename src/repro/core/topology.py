"""Hierarchical cluster topology descriptions.

The paper's system is a master <-> n edge nodes <-> m_i workers tree
(Fig. 1).  ``Topology`` is the single source of truth consumed by the
assignment/encoding/decoding modules, the runtime model, JNCSS, the
simulator and the distributed launcher (where edges map to pods and
workers map to data-parallel shard groups).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Topology:
    """A 2-level master/edge/worker tree.

    Attributes:
      m: tuple of per-edge worker counts ``(m_1, ..., m_n)``.
    """

    m: Tuple[int, ...]

    def __post_init__(self):
        if len(self.m) == 0:
            raise ValueError("topology needs at least one edge node")
        if any(mi <= 0 for mi in self.m):
            raise ValueError(f"worker counts must be positive, got {self.m}")

    @property
    def n(self) -> int:
        """Number of edge nodes."""
        return len(self.m)

    @property
    def m_min(self) -> int:
        """min_i m_i — the paper's ``m`` in straggler-tolerance domains."""
        return min(self.m)

    @property
    def total_workers(self) -> int:
        """Σ_i m_i."""
        return sum(self.m)

    def workers_of(self, i: int) -> int:
        """Worker count of edge node ``E_{i+1}`` (0-indexed here)."""
        return self.m[i]

    def worker_ids(self) -> List[Tuple[int, int]]:
        """All (edge, worker) index pairs, 0-indexed, row-major."""
        return [(i, j) for i in range(self.n) for j in range(self.m[i])]

    def flat_index(self, i: int, j: int) -> int:
        """Flatten (edge i, worker j) into a global worker index."""
        return sum(self.m[:i]) + j

    @staticmethod
    def uniform(n: int, m: int) -> "Topology":
        """n edges, m workers each (the paper's simulation setting)."""
        return Topology(m=(m,) * n)


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """Straggler tolerance levels ``(s_e, s_w)``.

    ``s_e ∈ [0 : n)`` straggling edge nodes, ``s_w ∈ [0 : min_i m_i)``
    straggling workers per edge node are tolerated (paper §II-A).
    """

    s_e: int
    s_w: int

    def validate(self, topo: Topology) -> "Tolerance":
        if not (0 <= self.s_e < topo.n):
            raise ValueError(f"s_e={self.s_e} outside [0:{topo.n})")
        if not (0 <= self.s_w < topo.m_min):
            raise ValueError(f"s_w={self.s_w} outside [0:{topo.m_min})")
        return self

    @property
    def f_e(self) -> int:
        raise AttributeError("use num_fast_edges(topo) — f_e depends on n")

    def num_fast_edges(self, topo: Topology) -> int:
        return topo.n - self.s_e

    def num_fast_workers(self, topo: Topology, i: int) -> int:
        return topo.m[i] - self.s_w

    def s_w_of(self, i: int) -> int:
        """Worker tolerance at edge ``i`` — uniform here; the grouped
        tolerance (:class:`repro.core.grouping.GroupTolerance`) overrides
        this per edge.  Decode paths call this instead of ``.s_w`` so
        both tolerance kinds ride the same code."""
        return self.s_w


def straggler_pattern_valid(
    topo: Topology,
    tol: Tolerance,
    edge_stragglers: Sequence[int],
    worker_stragglers: Sequence[Sequence[int]],
) -> bool:
    """True iff the given straggler pattern is within (s_e, s_w) tolerance.

    ``worker_stragglers[i]`` lists straggling workers of edge i.  Workers
    under a straggling edge are implicated (paper §I) and do not count
    against s_w.
    """
    if len(set(edge_stragglers)) > tol.s_e:
        return False
    for i in range(topo.n):
        if i in edge_stragglers:
            continue
        if len(set(worker_stragglers[i])) > tol.s_w:
            return False
    return True
