"""Data-partition assignment maps — paper eqs (15), (16), (18), (19).

The K disjoint sub-datasets are assigned cyclically:
  * edge node E_i receives n_i = K(s_e+1) m_i / Σ m_i parts           (15)
    at global offset Σ_{j<i} n_j (mod K)                               (16)
  * worker W_(i,j) receives D = n_i (s_w+1) / m_i of E_i's parts      (18)
    at local offset (j-1)·D (mod n_i)                                  (19)

All indices here are 0-based.  The cyclic construction covers every part
exactly (s_e+1) times across edges, and every edge-local part exactly
(s_w+1) times across that edge's workers — which is what makes the
two-layer code of §III feasible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.topology import Tolerance, Topology
from repro.core import tradeoff


@dataclasses.dataclass(frozen=True)
class Assignment:
    """Materialized assignment maps for a (topology, tolerance, K) triple."""

    topo: Topology
    tol: Tolerance
    K: int
    # edge_parts[i]  : ordered list of global part ids held by edge i (len n_i)
    edge_parts: Tuple[Tuple[int, ...], ...]
    # worker_local[i][j] : ordered local indices (into edge_parts[i]) of
    #                      worker (i, j)'s parts (len D)
    worker_local: Tuple[Tuple[Tuple[int, ...], ...], ...]

    @property
    def D(self) -> int:
        """Per-worker computational load."""
        return len(self.worker_local[0][0])

    def n_i(self, i: int) -> int:
        return len(self.edge_parts[i])

    def worker_parts(self, i: int, j: int) -> Tuple[int, ...]:
        """Global part ids processed by worker (i, j)."""
        ep = self.edge_parts[i]
        return tuple(ep[l] for l in self.worker_local[i][j])

    def parts_per_edge_cover(self) -> Dict[int, int]:
        """How many edges hold each part (must be s_e+1 everywhere)."""
        cover: Dict[int, int] = {k: 0 for k in range(self.K)}
        for parts in self.edge_parts:
            seen = set()
            for p in parts:
                if p not in seen:  # duplicates within an edge count once
                    cover[p] += 1
                    seen.add(p)
        return cover

    def local_cover(self, i: int) -> Dict[int, int]:
        """How many of edge i's workers hold each local part (s_w+1)."""
        cover: Dict[int, int] = {l: 0 for l in range(self.n_i(i))}
        for locs in self.worker_local[i]:
            for l in set(locs):
                cover[l] += 1
        return cover


def build_assignment(topo: Topology, tol: Tolerance, K: int) -> Assignment:
    """Build the cyclic assignment of paper §III-A.

    Raises ``ValueError`` when (topo, tol, K) violates the construction's
    integrality requirements — pick K with :func:`tradeoff.compatible_K`.
    """
    tol.validate(topo)
    if not tradeoff.feasible(topo, tol):
        raise ValueError(
            f"(s_e={tol.s_e}, s_w={tol.s_w}) infeasible for topology {topo.m}: "
            "not enough workers among the slowest f_e edges (paper §II-B)"
        )
    tot = topo.total_workers
    edge_parts: List[Tuple[int, ...]] = []
    offset = 0
    for i in range(topo.n):
        num = K * (tol.s_e + 1) * topo.m[i]
        if num % tot != 0:
            raise ValueError(
                f"n_i for edge {i} not integral (K={K}); use compatible_K()"
            )
        ni = num // tot
        if ni > K:
            raise ValueError(
                f"edge {i} would be assigned n_i={ni} > K={K} parts; "
                "topology too skewed for this tolerance"
            )
        edge_parts.append(tuple((offset + t) % K for t in range(ni)))
        offset += ni
    # sanity: Σ n_i = K (s_e + 1)
    assert offset == K * (tol.s_e + 1)

    worker_local: List[Tuple[Tuple[int, ...], ...]] = []
    D_ref = None
    for i in range(topo.n):
        ni = len(edge_parts[i])
        mi = topo.m[i]
        num = ni * (tol.s_w + 1)
        if num % mi != 0:
            raise ValueError(
                f"D for edge {i} not integral (n_i={ni}, m_i={mi}); "
                "use compatible_K()"
            )
        D = num // mi
        if D_ref is None:
            D_ref = D
        elif D != D_ref:  # construction guarantees equality; guard anyway
            raise ValueError(f"unequal per-worker loads {D} != {D_ref}")
        rows = []
        for j in range(mi):
            rows.append(tuple((j * D + t) % ni for t in range(D)))
        worker_local.append(tuple(rows))

    asg = Assignment(
        topo=topo,
        tol=tol,
        K=K,
        edge_parts=tuple(edge_parts),
        worker_local=tuple(worker_local),
    )
    _check_covers(asg)
    return asg


def assignment_from_supports(
    topo: Topology,
    tol: Tolerance,
    K: int,
    edge_supports: Tuple[Tuple[int, ...], ...],
    worker_supports: Tuple[Tuple[Tuple[int, ...], ...], ...],
) -> Assignment:
    """Build an Assignment directly from code supports.

    Used by non-cyclic constructions (e.g. fractional repetition) where
    the code's support structure *defines* the data placement.
    ``worker_supports[i][j]`` are local indices into ``edge_supports[i]``.
    """
    asg = Assignment(
        topo=topo,
        tol=tol,
        K=K,
        edge_parts=edge_supports,
        worker_local=worker_supports,
    )
    _check_covers(asg)
    return asg


def _check_covers(asg: Assignment) -> None:
    """Internal invariants: exact (s_e+1)- and (s_w+1)-fold covers."""
    cover = asg.parts_per_edge_cover()
    want = asg.tol.s_e + 1
    bad = {k: c for k, c in cover.items() if c != want}
    if bad:
        raise AssertionError(f"edge cover != s_e+1={want}: {bad}")
    for i in range(asg.topo.n):
        lc = asg.local_cover(i)
        want_w = asg.tol.s_w + 1
        bad = {l: c for l, c in lc.items() if c != want_w}
        if bad:
            raise AssertionError(f"edge {i} local cover != s_w+1: {bad}")
