"""Encoding-matrix construction — paper §III-A (Conditions 1 & 2).

Two constructions are provided for a support-constrained code whose every
``f``-row subset must span the all-ones vector:

* ``random``  — paper-faithful generic construction: i.i.d. Gaussian
  coefficients on the prescribed (cyclic) supports.  Condition 1/2 holds
  with probability 1 (the supports cover every column ≥ s+1 times, so the
  span property is generic); we *verify* it explicitly after construction
  and re-seed on the (measure-zero) failure event.  Decoding uses
  least-squares in float64 — residuals are checked to be numerically zero.

* ``frc``     — fractional-repetition code (Tandon et al. [14]): when
  (s+1) | rows and the supports can be organized as s+1 groups each
  partitioning the columns, all coefficients are 1 and decoding weights
  are exactly {0, 1}.  Perfectly conditioned — the right choice for bf16
  gradient payloads at scale.  Used when divisibility permits and the
  caller opts in (beyond-paper robustness feature; the *paper's* cyclic
  supports remain the default).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

import numpy as np

# Residual threshold for "exact" float64 decode.
_DECODE_RTOL = 1e-8
# Max number of subsets to exhaustively verify; sample beyond this.
_MAX_EXHAUSTIVE = 512


class CodeConstructionError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class LinearCode:
    """A support-constrained code: ``matrix`` rows combine column-items.

    Guarantee (verified at construction): for any ``num_rows - s`` rows,
    the all-ones row vector lies in their span.
    """

    matrix: np.ndarray  # (rows, cols) float64
    supports: Tuple[Tuple[int, ...], ...]  # per-row non-zero columns
    s: int  # tolerated straggling rows

    @property
    def rows(self) -> int:
        return self.matrix.shape[0]

    @property
    def cols(self) -> int:
        return self.matrix.shape[1]

    @property
    def f(self) -> int:
        """Number of rows needed to decode."""
        return self.rows - self.s

    def decode_vector(self, fast_rows: Sequence[int]) -> np.ndarray:
        """Solve a · M[fast] = 1 (least squares, residual-checked)."""
        fast = sorted(set(fast_rows))
        if len(fast) < self.f:
            raise ValueError(
                f"need ≥ {self.f} rows to decode, got {len(fast)}"
            )
        sub = self.matrix[fast, :]  # (f', cols)
        ones = np.ones(self.cols, dtype=np.float64)
        a, *_ = np.linalg.lstsq(sub.T, ones, rcond=None)
        resid = float(np.max(np.abs(a @ sub - ones)))
        if resid > _DECODE_RTOL:
            raise CodeConstructionError(
                f"decode failed for rows {fast}: residual {resid:.2e}"
            )
        return a

    def full_decode_weights(self, fast_rows: Sequence[int]) -> np.ndarray:
        """Length-``rows`` decode vector, zero on straggling rows."""
        a = self.decode_vector(fast_rows)
        w = np.zeros(self.rows, dtype=np.float64)
        for weight, r in zip(a, sorted(set(fast_rows))):
            w[r] = weight
        return w


def cyclic_supports(
    cols: int, sizes: Sequence[int], offsets: Optional[Sequence[int]] = None
) -> Tuple[Tuple[int, ...], ...]:
    """Cyclic windows over ``cols`` columns (paper eqs (16)/(19))."""
    out: List[Tuple[int, ...]] = []
    off = 0
    for r, size in enumerate(sizes):
        start = offsets[r] if offsets is not None else off
        out.append(tuple((start + t) % cols for t in range(size)))
        off += size
    return tuple(out)


def _segments_by_cover(
    supports: Sequence[Sequence[int]], cols: int
) -> Tuple[List[List[int]], List[Tuple[int, ...]]]:
    """Group columns by the exact set of rows covering them.

    The cyclic assignment (eqs 16/19) produces at most ``len(supports)``
    distinct cover-sets, collapsing the K-column construction problem to
    a small segment-level one (this is what makes the paper's Example 1
    coefficients piecewise-constant).
    Returns (segment -> column list, segment -> covering row tuple).
    """
    cover_of_col: List[Tuple[int, ...]] = []
    col_rows: List[List[int]] = [[] for _ in range(cols)]
    for r, sup in enumerate(supports):
        for c in set(sup):
            col_rows[c].append(r)
    seg_index: dict = {}
    seg_cols: List[List[int]] = []
    seg_cover: List[Tuple[int, ...]] = []
    for c in range(cols):
        key = tuple(col_rows[c])
        if not key:
            raise CodeConstructionError(f"column {c} covered by no row")
        if key not in seg_index:
            seg_index[key] = len(seg_cols)
            seg_cols.append([])
            seg_cover.append(key)
        seg_cols[seg_index[key]].append(c)
    return seg_cols, seg_cover


def build_random_code(
    supports: Sequence[Sequence[int]],
    cols: int,
    s: int,
    seed: int = 0,
    max_retries: int = 16,
) -> LinearCode:
    """Span-condition code on the given supports (null-space construction).

    Segment reduction first: columns with identical cover-sets share one
    coefficient per row.  At segment level (n_seg segments, f = rows−s
    needed rows) we pick a subspace ``V = null(H)`` with ``H·1 = 0`` and
    draw each row's segment-coefficients randomly *inside* V restricted
    to its segment support — so every f-row subset generically spans V ∋ 1.
    When f ≥ n_seg (no H needed) plain random coefficients suffice.
    The span condition is verified explicitly; re-seeded on failure.
    """
    rows = len(supports)
    if not 0 <= s < rows:
        raise ValueError(f"s={s} outside [0:{rows})")
    f = rows - s
    seg_cols, seg_cover = _segments_by_cover(supports, cols)
    n_seg = len(seg_cols)
    # segment-level supports
    row_segs: List[List[int]] = [[] for _ in range(rows)]
    for t, cov in enumerate(seg_cover):
        for r in cov:
            row_segs[r].append(t)
    q = n_seg - f  # codim of the common subspace V within segment space

    rng = np.random.default_rng(seed)
    for _attempt in range(max_retries):
        seg_mat = np.zeros((rows, n_seg), dtype=np.float64)
        if q <= 0 or any(len(rs) <= q for rs in row_segs):
            # f ≥ n_seg (or a row too narrow for the H-method): plain
            # random coefficients; verification gates correctness.
            for r in range(rows):
                seg_mat[r, row_segs[r]] = rng.normal(size=len(row_segs[r]))
        else:
            # H q×n_seg with H·1 = 0 ⇒ 1 ∈ V = null(H), dim V = f.
            H = rng.normal(size=(q, n_seg))
            H[:, -1] -= H.sum(axis=1)  # rows sum to 0
            for r in range(rows):
                sub = H[:, row_segs[r]]  # q × |C_r|
                # random vector in null(sub): |C_r| > q ⇒ dim ≥ 1
                _u, sv, vt = np.linalg.svd(sub, full_matrices=True)
                null_dim = vt.shape[0] - np.sum(sv > 1e-12)
                if null_dim < 1:
                    break
                basis = vt[vt.shape[0] - null_dim:, :].T  # |C_r| × null_dim
                vec = basis @ rng.normal(size=null_dim)
                seg_mat[r, row_segs[r]] = vec
            else:
                pass
        # normalize rows for conditioning (scale-invariant condition)
        norms = np.linalg.norm(seg_mat, axis=1, keepdims=True)
        norms[norms == 0] = 1.0
        seg_mat = seg_mat / norms * np.sqrt(n_seg)
        # expand segments to columns
        mat = np.zeros((rows, cols), dtype=np.float64)
        for t, cs in enumerate(seg_cols):
            mat[:, cs] = seg_mat[:, [t]]
        code = LinearCode(
            matrix=mat,
            supports=tuple(tuple(sup) for sup in supports),
            s=s,
        )
        if verify_span_condition(code):
            return code
    raise CodeConstructionError(
        f"failed to build a valid code after {max_retries} seeds "
        f"(rows={rows}, cols={cols}, s={s}, n_seg={n_seg})"
    )


def build_replication_code(
    supports: Sequence[Sequence[int]], cols: int
) -> LinearCode:
    """s=0 code: coefficients all 1; decode = plain sum.

    Valid when the supports *partition* the columns (each column covered
    exactly once) — the Uncoded / s=0 case.
    """
    rows = len(supports)
    cover = np.zeros(cols, dtype=np.int64)
    mat = np.zeros((rows, cols), dtype=np.float64)
    for r, sup in enumerate(supports):
        mat[r, list(sup)] = 1.0
        cover[list(sup)] += 1
    if not np.all(cover == 1):
        raise CodeConstructionError("supports do not partition the columns")
    return LinearCode(matrix=mat, supports=tuple(map(tuple, supports)), s=0)


def build_frc_code(rows: int, cols: int, s: int) -> LinearCode:
    """Fractional-repetition code (all-ones coefficients, {0,1} decode).

    Requires (s+1) | rows and (rows/(s+1)) | cols.  Rows are organized
    into s+1 groups; each group partitions the columns equally.
    """
    if (s + 1) <= 0 or rows % (s + 1) != 0:
        raise CodeConstructionError(f"(s+1)={s+1} must divide rows={rows}")
    per_group = rows // (s + 1)
    if cols % per_group != 0:
        raise CodeConstructionError(
            f"group size {per_group} must divide cols={cols}"
        )
    width = cols // per_group
    mat = np.zeros((rows, cols), dtype=np.float64)
    supports: List[Tuple[int, ...]] = []
    r = 0
    for _g in range(s + 1):
        for k in range(per_group):
            sup = tuple(range(k * width, (k + 1) * width))
            mat[r, list(sup)] = 1.0
            supports.append(sup)
            r += 1
    return LinearCode(matrix=mat, supports=tuple(supports), s=s)


def frc_decode_weights(code: LinearCode, fast_rows: Sequence[int]) -> np.ndarray:
    """Combinatorial {0,1} decode for FRC codes: pick one complete group."""
    fast = set(fast_rows)
    per_group = code.rows // (code.s + 1)
    for g in range(code.s + 1):
        members = list(range(g * per_group, (g + 1) * per_group))
        if all(m in fast for m in members):
            w = np.zeros(code.rows, dtype=np.float64)
            w[members] = 1.0
            return w
    raise CodeConstructionError(
        f"no complete group among fast rows {sorted(fast)}"
    )


def verify_span_condition(
    code: LinearCode, rng: Optional[np.random.Generator] = None
) -> bool:
    """Check Condition 1/2: every f-subset of rows spans the ones vector.

    Exhaustive when C(rows, f) ≤ 512, else randomized subset sampling
    (512 samples) — failures are measure-zero for the random construction,
    and downstream ``decode_vector`` residual checks give a second gate.
    """
    rows, f = code.rows, code.f
    all_subsets = itertools.combinations(range(rows), f)
    import math

    n_total = math.comb(rows, f)
    if n_total <= _MAX_EXHAUSTIVE:
        subsets = list(all_subsets)
    else:
        rng = rng or np.random.default_rng(1234)
        subsets = [
            tuple(sorted(rng.choice(rows, size=f, replace=False)))
            for _ in range(_MAX_EXHAUSTIVE)
        ]
    ones = np.ones(code.cols, dtype=np.float64)
    for sub in subsets:
        m = code.matrix[list(sub), :]
        a, *_ = np.linalg.lstsq(m.T, ones, rcond=None)
        if np.max(np.abs(a @ m - ones)) > _DECODE_RTOL:
            return False
    return True
