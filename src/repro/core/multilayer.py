"""Corollary 2 — L-layer hierarchical gradient coding.

The paper proves the L-layer bound D/K ≥ Π_l (s_l+1)/W and leaves the
construction implicit; this module provides it by recursing the
two-layer construction: each level ℓ applies a span-condition code over
its children's part-sets, exactly as B/D̄ do for L = 2.

A 3-level deployment maps naturally to (pod, host, chip) — the paper's
"future work" direction, built here as a beyond-paper feature.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.encoding import (
    LinearCode,
    build_random_code,
    build_replication_code,
    cyclic_supports,
)


@dataclasses.dataclass(frozen=True)
class TreeNode:
    """A node in the hierarchy: either an internal node or a worker leaf."""

    children: Tuple["TreeNode", ...] = ()

    @property
    def is_leaf(self) -> bool:
        return not self.children

    def num_leaves(self) -> int:
        if self.is_leaf:
            return 1
        return sum(c.num_leaves() for c in self.children)

    @staticmethod
    def uniform(branching: Sequence[int]) -> "TreeNode":
        """Build a uniform tree, e.g. (2, 4, 8): 2 pods × 4 hosts × 8 chips."""
        if not branching:
            return TreeNode()
        return TreeNode(
            children=tuple(
                TreeNode.uniform(branching[1:]) for _ in range(branching[0])
            )
        )


@dataclasses.dataclass(frozen=True)
class MultiLayerCode:
    """Recursive span-condition code over an L-level tree."""

    tree: TreeNode
    s: Tuple[int, ...]  # per-level straggler tolerance (root-first)
    K: int
    # per internal node (in DFS preorder): the code over its children
    codes: Tuple[LinearCode, ...]
    # leaf → effective coefficient vector over the K parts
    leaf_coeffs: np.ndarray  # (n_leaves, K)
    leaf_parts: Tuple[Tuple[int, ...], ...]

    @property
    def load(self) -> int:
        return len(self.leaf_parts[0])

    @staticmethod
    def build(
        tree: TreeNode, s: Sequence[int], K: int, seed: int = 0
    ) -> "MultiLayerCode":
        codes: List[LinearCode] = []
        leaf_coeffs: List[np.ndarray] = []
        leaf_parts: List[Tuple[int, ...]] = []

        def recurse(node: TreeNode, level: int, parts: Tuple[int, ...],
                    coeff: np.ndarray, rng_seed: int):
            if node.is_leaf:
                leaf_coeffs.append(coeff)
                leaf_parts.append(parts)
                return
            n = len(node.children)
            s_l = s[level]
            if not 0 <= s_l < n:
                raise ValueError(f"s[{level}]={s_l} outside [0:{n})")
            cols = len(parts)
            per = cols * (s_l + 1)
            if per % n:
                raise ValueError(
                    f"level {level}: {cols} parts × (s+1) not divisible "
                    f"by {n} children"
                )
            width = per // n
            sup = cyclic_supports(cols, [width] * n)
            if s_l == 0:
                code = build_replication_code(sup, cols)
            else:
                code = build_random_code(sup, cols, s_l, seed=rng_seed)
            codes.append(code)
            for ci, child in enumerate(node.children):
                child_local = sup[ci]
                child_parts = tuple(parts[j] for j in child_local)
                # effective coefficient: path-product in GLOBAL indices
                child_full = np.zeros(K)
                for j_local in child_local:
                    child_full[parts[j_local]] += code.matrix[ci, j_local]
                child_coeff = coeff * child_full
                recurse(child, level + 1, child_parts,
                        child_coeff, rng_seed * 131 + ci + 1)

        root_coeff = np.ones(K)
        recurse(tree, 0, tuple(range(K)), root_coeff, seed + 1)
        # leaf coeffs are over the global K indices already
        return MultiLayerCode(
            tree=tree,
            s=tuple(s),
            K=K,
            codes=tuple(codes),
            leaf_coeffs=np.stack(leaf_coeffs),
            leaf_parts=tuple(
                tuple(k for k in range(K) if lc[k] != 0.0)
                for lc in leaf_coeffs
            ),
        )

    # ------------------------------------------------------------------
    def decode(
        self,
        g_parts: np.ndarray,  # (K, dim)
        dead_per_level: Optional[Dict[int, set]] = None,
        _node: Optional[TreeNode] = None,
        _level: int = 0,
        _code_idx: Optional[List[int]] = None,
        _parts: Optional[Tuple[int, ...]] = None,
        _leaf_counter: Optional[List[int]] = None,
    ) -> np.ndarray:
        """Recursive decode with per-level straggler sets.

        ``dead_per_level[ℓ]`` holds (preorder child indices at level ℓ)
        that straggled; at most s[ℓ] per parent are tolerated.
        """
        dead_per_level = dead_per_level or {}
        if _node is None:
            _node, _code_idx, _parts = self.tree, [0], tuple(range(self.K))
            _leaf_counter = [0]
        node, parts = _node, _parts
        if node.is_leaf:
            i = _leaf_counter[0]
            _leaf_counter[0] += 1
            return self.leaf_coeffs[i] @ g_parts
        code = self.codes[_code_idx[0]]
        _code_idx[0] += 1
        results = {}
        dead = dead_per_level.get(_level, set())
        for ci, child in enumerate(node.children):
            sub = self.decode(
                g_parts, dead_per_level, child, _level + 1, _code_idx,
                tuple(parts[j] for j in code.supports[ci]), _leaf_counter,
            )
            results[ci] = sub
        alive = [ci for ci in results if ci not in dead]
        f = code.f
        fast = alive[:f] if len(alive) >= f else alive
        w = code.full_decode_weights(fast)
        out = None
        for ci in fast:
            term = w[ci] * results[ci]
            out = term if out is None else out + term
        return out


def min_load_fraction(branching: Sequence[int],
                      s: Sequence[int]) -> Fraction:
    """Corollary 2 bound for a uniform tree."""
    W = 1
    for b in branching:
        W *= b
    num = 1
    for s_l in s:
        num *= s_l + 1
    return Fraction(num, W)
