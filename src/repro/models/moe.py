"""Mixture-of-Experts layer: top-k routing with capacity, sort-based
dispatch (gather/scatter — no O(N·E·C) one-hot einsums, which would
dwarf the useful expert FLOPs at E=128).

Sharding intent: expert-parallel over the ``model`` mesh axis when
``n_experts`` divides it (llama4's 128e), otherwise experts replicated.
The pjit path leaves the dispatch gathers to SPMD; the dist path
(``ShardCtx`` active, inside shard_map) runs explicit expert
parallelism: the router is column-parallel with its logits all-gathered
(routing and the load-balancing aux loss need the full expert axis),
every shard dispatches only to its own expert block, and the partial
expert outputs — plus the column/row-parallel shared-expert branch —
are combined by a single psum over the model axis.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import NULL_CTX


def init_moe(rng, d: int, ff: int, E: int, n_shared: int, dtype) -> Dict:
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    scale = 0.02
    p = {
        "router": (jax.random.normal(k1, (d, E)) * scale).astype(dtype),
        "we_g": (jax.random.normal(k2, (E, d, ff)) * scale).astype(dtype),
        "we_u": (jax.random.normal(k3, (E, d, ff)) * scale).astype(dtype),
        "we_d": (jax.random.normal(k4, (E, ff, d)) * scale).astype(dtype),
    }
    if n_shared:
        ks = jax.random.split(k5, 3)
        p["ws_g"] = (jax.random.normal(ks[0], (d, ff * n_shared)) * scale
                     ).astype(dtype)
        p["ws_u"] = (jax.random.normal(ks[1], (d, ff * n_shared)) * scale
                     ).astype(dtype)
        p["ws_d"] = (jax.random.normal(ks[2], (ff * n_shared, d)) * scale
                     ).astype(dtype)
    return p


def moe_ffn(
    params: Dict,
    x: jnp.ndarray,  # (B, S, d)
    top_k: int,
    capacity_factor: float = 1.25,
    ctx=NULL_CTX,
    shared_width: Optional[int] = None,  # global n_shared·ff (TP detect)
    n_experts: Optional[int] = None,     # global E (TP detect)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), load-balancing aux loss scalar).

    SP (ctx.sp): ``x`` arrives as the local seq block; the layer
    gathers the full sequence ONCE up front — routing, the router
    logits and the load-balancing aux statistics all need every token
    (the aux loss must stay identical across shards) — and the final
    combine reduce-scatters back to the local seq block.
    """
    x = ctx.gather_seq(x)
    B, S, d = x.shape
    N = B * S
    xf = x.reshape(N, d)

    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    # TP: when the router is column-parallel (E divides tp), gather the
    # expert axis so routing/top-k/aux see all experts (E is small;
    # (N, E) is cheap).  When fit_pspecs dropped the expert sharding
    # (E % tp != 0) the logits are already full-width — gathering again
    # would duplicate experts and corrupt the routing.
    if (ctx.active and n_experts is not None
            and logits.shape[-1] != n_experts):
        logits = ctx.all_gather(logits, axis=-1)
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)
    top_p, top_e = jax.lax.top_k(probs, top_k)  # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balancing loss (Switch-style): E · Σ_e f_e · P_e ----
    pe = probs.mean(axis=0)
    fe = jnp.zeros(E).at[top_e.reshape(-1)].add(1.0) / (N * top_k)
    aux = E * jnp.sum(fe * pe)

    # ---- sort-based dispatch with capacity -----------------------------
    # TP: each shard owns the contiguous expert block [e0, e0+E_local);
    # routing stays global, the dispatch keeps only local experts and
    # the partial outputs are psum'd below.
    E_local = params["we_g"].shape[0]
    experts_sharded = ctx.active and E_local != E
    e0 = ctx.axis_index() * E_local if experts_sharded else 0
    cap = int(max(1, capacity_factor * N * top_k / E))
    flat_e = top_e.reshape(-1)  # (N·k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within expert = index in sorted stream − expert segment start
    counts = jnp.zeros(E, jnp.int32).at[flat_e].add(1)
    seg_start = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]]
    )
    rank = jnp.arange(N * top_k) - seg_start[sorted_e]
    keep = rank < cap
    if experts_sharded:
        keep = keep & (sorted_e >= e0) & (sorted_e < e0 + E_local)
    slot = jnp.where(
        keep, (sorted_e - e0) * cap + rank, E_local * cap
    )  # sentinel last

    tok_of_slot = order // top_k  # original token of each sorted entry
    buf = jnp.zeros((E_local * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[tok_of_slot])
    buf = buf[: E_local * cap].reshape(E_local, cap, d)

    # ---- expert FFN (swiglu), batched over experts ---------------------
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, params["we_g"])
    ) * jnp.einsum("ecd,edf->ecf", buf, params["we_u"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["we_d"])
    out_buf = jnp.concatenate(
        [out_buf.reshape(E_local * cap, d), jnp.zeros((1, d), out_buf.dtype)],
        0,
    )

    # ---- combine: gather back, weight, sum over the k copies -----------
    # per sorted entry: its slot (or sentinel), weight from router
    w_sorted = top_p.reshape(-1)[order]
    contrib = out_buf[slot] * w_sorted[:, None].astype(out_buf.dtype)
    y = jnp.zeros((N, d), out_buf.dtype).at[tok_of_slot].add(contrib)

    # ---- shared experts (llama4) ---------------------------------------
    sh = None
    sh_sharded = False
    if "ws_g" in params:
        sh = jax.nn.silu(xf @ params["ws_g"]) * (xf @ params["ws_u"])
        sh = sh @ params["ws_d"]
        sh_sharded = (ctx.active and shared_width is not None
                      and params["ws_g"].shape[-1] != shared_width)
    # combine with a single collective over the model axis: partial
    # terms (sharded experts / column-row-parallel shared branch) sum
    # inside, replicated terms stay outside.  Under SP the psum becomes
    # a reduce-scatter over seq and replicated terms slice their local
    # seq block — combine at (B, S, d) so the seq axis is addressable.
    partial = [t.reshape(B, S, d)
               for t, p in ((y, experts_sharded), (sh, sh_sharded)) if p]
    full = [t.reshape(B, S, d)
            for t, p in ((y, experts_sharded), (sh, sh_sharded))
            if t is not None and not p]
    if partial:
        terms = [ctx.psum_scatter(partial[0] if len(partial) == 1
                                  else partial[0] + partial[1])]
        terms += [ctx.scatter_seq(t) for t in full]
    else:
        terms = [ctx.scatter_seq(t) for t in full]
    y = terms[0] if len(terms) == 1 else terms[0] + terms[1]
    return y.astype(x.dtype), aux


def moe_ffn_reference(params, x, top_k):
    """Dense oracle: every expert on every token, masked by routing.

    O(N·E) compute — tests only.  No capacity drops (compare with
    capacity_factor large enough that nothing is dropped).
    """
    B, S, d = x.shape
    E = params["router"].shape[1]
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    gate = jnp.zeros_like(probs)
    gate = jax.vmap(lambda g, e, p: g.at[e].set(p))(gate, top_e, top_p)
    h = jax.nn.silu(
        jnp.einsum("nd,edf->enf", xf, params["we_g"])
    ) * jnp.einsum("nd,edf->enf", xf, params["we_u"])
    per_e = jnp.einsum("enf,efd->end", h, params["we_d"])
    y = jnp.einsum("end,ne->nd", per_e, gate.astype(per_e.dtype))
    if "ws_g" in params:
        sh = jax.nn.silu(xf @ params["ws_g"]) * (xf @ params["ws_u"])
        y = y + sh @ params["ws_d"]
    return y.reshape(B, S, d).astype(x.dtype)
