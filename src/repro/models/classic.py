"""The paper's own evaluation models (§V-A):

  * logistic regression for MNIST (784 → 10),
  * a CNN with 6 convolution layers and 3 fully-connected layers for
    CIFAR-10 (32×32×3 → 10).

Pure JAX (init/apply pairs + softmax-CE loss), used by the simulation
benchmarks (Figs. 5/6, Table I) and the HGC training examples.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_logreg(rng, n_features: int = 784, n_classes: int = 10) -> Dict:
    return {
        "w": jax.random.normal(rng, (n_features, n_classes)) * 0.01,
        "b": jnp.zeros((n_classes,)),
    }


def apply_logreg(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return x.reshape(x.shape[0], -1) @ params["w"] + params["b"]


def init_cnn(rng, in_ch: int = 3, n_classes: int = 10) -> Dict:
    """6 conv layers + 3 FC layers (paper's CIFAR-10 model)."""
    chans = [in_ch, 32, 32, 64, 64, 128, 128]
    ks = jax.random.split(rng, 9)
    params: Dict = {}
    for i in range(6):
        fan_in = chans[i] * 9
        params[f"conv{i}"] = {
            "w": jax.random.normal(ks[i], (3, 3, chans[i], chans[i + 1]))
            * jnp.sqrt(2.0 / fan_in),
            "b": jnp.zeros((chans[i + 1],)),
        }
    # 32×32 → pool after conv1, conv3, conv5 → 4×4×128 = 2048
    dims = [2048, 256, 128, n_classes]
    for i in range(3):
        params[f"fc{i}"] = {
            "w": jax.random.normal(ks[6 + i], (dims[i], dims[i + 1]))
            * jnp.sqrt(2.0 / dims[i]),
            "b": jnp.zeros((dims[i + 1],)),
        }
    return params


def apply_cnn(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, 32, 32, 3) → logits (B, 10)."""

    def conv(p, h):
        return jax.lax.conv_general_dilated(
            h, p["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + p["b"]

    def pool(h):
        return jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )

    h = x
    for i in range(6):
        h = jax.nn.relu(conv(params[f"conv{i}"], h))
        if i % 2 == 1:
            h = pool(h)
    h = h.reshape(h.shape[0], -1)
    for i in range(3):
        h = h @ params[f"fc{i}"]["w"] + params[f"fc{i}"]["b"]
        if i < 2:
            h = jax.nn.relu(h)
    return h


def xent_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return (logits.argmax(-1) == labels).mean()


def grad_fn(apply, params, x, y):
    """Gradient of mean CE loss — the g_k of paper eq. (2)."""

    def loss(p):
        return xent_loss(apply(p, x), y)

    return jax.grad(loss)(params)
