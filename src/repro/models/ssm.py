"""Mamba-2 (SSD — state-space duality) block, chunked matmul form.

Faithful to the SSD algorithm of arXiv:2405.21060 (minimal form):
per-head scalar decay  dA_t = exp(dt_t · A),  inputs discretized as
x̄_t = dt_t · x_t, state  H_t = dA_t·H_{t−1} + x̄_t ⊗ B_t,
output y_t = C_t · H_t + D · x_t.

The chunked form splits the sequence into chunks of Q tokens:
  * intra-chunk:  Y_in = ((C Bᵀ) ⊙ L) x̄   (quadratic within the chunk —
    MXU-friendly matmuls; L is the decay lower-triangle),
  * inter-chunk:  per-chunk states are propagated by a short lax.scan.

Decode is the O(1) recurrent update on a carried (B, nh, hd, N) state.
TPU adaptation note: chunk size is chosen so the intra-chunk matrices
(Q×Q and hd×N) are multiples of the MXU tile; no custom kernel needed —
the SSD form is already matmul-dominant, which is the paper's own point.

Tensor parallelism (dist path, ``ShardCtx`` active): the projections are
head-block structured — ``zproj``/``xproj``/``dtproj`` (and the xs
depthwise conv) are column-parallel over whole SSD heads, B/C streams
(``bcproj`` + their conv) replicate (they are shared across heads in the
minimal SSD form), per-head vectors (A_log, D, dt_bias) are sliced to
the local head block, and ``out_proj`` is row-parallel with one psum.
This per-segment split is exactly why the in-projection is separate
leaves instead of one fused matrix: a blockwise shard of the fused
``in_proj`` would cut across the z/x/B/C/dt segment boundaries.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import NULL_CTX


def init_ssm(rng, d: int, expand: int, d_state: int, d_conv: int,
             head_dim: int, dtype) -> Dict:
    di = expand * d
    nh = di // head_dim
    ks = jax.random.split(rng, 7)
    scale = 0.02
    return {
        # column-parallel, head-block structured (see module docstring)
        "zproj": (jax.random.normal(ks[0], (d, di)) * scale).astype(dtype),
        "xproj": (jax.random.normal(ks[1], (d, di)) * scale).astype(dtype),
        # B/C streams: shared across heads ⇒ replicated under TP
        "bcproj": (jax.random.normal(ks[2], (d, 2 * d_state)) * scale
                   ).astype(dtype),
        "dtproj": (jax.random.normal(ks[3], (d, nh)) * scale).astype(dtype),
        "conv_x_w": (jax.random.normal(ks[4], (d_conv, di)) * scale
                     ).astype(dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": (jax.random.normal(ks[5], (d_conv, 2 * d_state))
                      * scale).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * d_state,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)
        ),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": (jax.random.normal(ks[6], (di, d)) * scale).astype(dtype),
    }


def _causal_conv(seq: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv along time: seq (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(seq)
    for k in range(K):  # K=4: unrolled adds, fuses well
        out = out + pad[:, k : k + seq.shape[1], :] * w[k]
    return jax.nn.silu(out + b)


def _segsum(logdA: jnp.ndarray) -> jnp.ndarray:
    """L[i,j] = exp(Σ_{k=j+1..i} logdA_k) for j ≤ i else 0. (..., Q, Q)."""
    Q = logdA.shape[-1]
    cs = jnp.cumsum(logdA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # Σ_{j+1..i}
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(
    xbar: jnp.ndarray,  # (B, S, nh, hd)  = dt · x
    logdA: jnp.ndarray,  # (B, S, nh)      = dt · A  (A < 0)
    Bc: jnp.ndarray,  # (B, S, N)
    Cc: jnp.ndarray,  # (B, S, N)
    chunk: int,
    h0: jnp.ndarray = None,  # (B, nh, hd, N) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan; returns (y (B,S,nh,hd), final state)."""
    B, S, nh, hd = xbar.shape
    N = Bc.shape[-1]
    assert S % chunk == 0, (S, chunk)
    c = S // chunk
    xb = xbar.reshape(B, c, chunk, nh, hd).astype(jnp.float32)
    la = logdA.reshape(B, c, chunk, nh).astype(jnp.float32)
    Bb = Bc.reshape(B, c, chunk, N).astype(jnp.float32)
    Cb = Cc.reshape(B, c, chunk, N).astype(jnp.float32)

    # intra-chunk (dual / attention-like form)
    L = _segsum(jnp.moveaxis(la, -1, -2))  # (B, c, nh, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cb, Bb)  # (B,c,Q,Q)
    M = scores[:, :, None] * L  # (B,c,nh,Q,Q)
    y_in = jnp.einsum("bchqk,bckhd->bcqhd", M, xb)

    # per-chunk summarized state:  S_c = Σ_j decay_to_end_j · x̄_j ⊗ B_j
    cs = jnp.cumsum(la, axis=2)  # (B,c,Q,nh)
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)  # decay from j to chunk end
    S_c = jnp.einsum(
        "bcqh,bcqhd,bcqn->bchdn", decay_end, xb, Bb
    )  # (B,c,nh,hd,N)
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (B,c,nh) total chunk decay

    # inter-chunk recurrence over c chunks
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, N), jnp.float32)

    def body(h, inputs):
        s_c, dec = inputs  # (B,nh,hd,N), (B,nh)
        h_new = h * dec[..., None, None] + s_c
        return h_new, h  # emit state *entering* the chunk

    (h_final, h_enter) = lax.scan(
        body,
        h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_enter = jnp.moveaxis(h_enter, 0, 1)  # (B,c,nh,hd,N)

    # contribution of the entering state within each chunk
    decay_in = jnp.exp(cs)  # decay from chunk start to position q
    y_out = jnp.einsum(
        "bcqn,bchdn,bcqh->bcqhd", Cb, h_enter, decay_in
    )
    y = (y_in + y_out).reshape(B, S, nh, hd)
    return y, h_final


def ssd_reference(xbar, logdA, Bc, Cc, h0=None):
    """Naive per-token recurrence — oracle for the chunked form."""
    B, S, nh, hd = xbar.shape
    N = Bc.shape[-1]
    h = (jnp.zeros((B, nh, hd, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    ys = []
    for t in range(S):
        dA = jnp.exp(logdA[:, t].astype(jnp.float32))  # (B,nh)
        h = h * dA[..., None, None] + jnp.einsum(
            "bhd,bn->bhdn", xbar[:, t].astype(jnp.float32),
            Bc[:, t].astype(jnp.float32),
        )
        ys.append(jnp.einsum("bhdn,bn->bhd", h, Cc[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1), h


def _head_params(params: Dict, nh_local: int, ctx):
    """Per-head vectors sliced to this shard's head block (TP no-op
    when the projections are unsharded)."""
    A_log = ctx.local_block(params["A_log"], nh_local)
    D = ctx.local_block(params["D"], nh_local)
    dt_bias = ctx.local_block(params["dt_bias"], nh_local)
    return A_log, D, dt_bias


def ssm_forward(
    params: Dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    ctx=NULL_CTX,
) -> jnp.ndarray:
    """Full-sequence Mamba-2 block (train / prefill).

    SP (ctx.sp): the SSD recurrence is sequential in seq, so the block
    cannot keep the sequence sharded through the scan — it gathers the
    full sequence up front (the ctx-driven fallback) and the
    row-parallel out-projection reduce-scatters back to the local seq
    block; only the norm/residual work *between* blocks shards.
    """
    x = ctx.gather_seq(x)  # gather-before-scan: the scan needs all of S
    hd = cfg.ssm_head_dim
    z = x @ params["zproj"]      # (B, S, di_local)
    xs = x @ params["xproj"]     # (B, S, di_local)
    bc = x @ params["bcproj"]    # (B, S, 2N) replicated under TP
    dt = x @ params["dtproj"]    # (B, S, nh_local)
    di_l = xs.shape[-1]
    nh_l = di_l // hd
    xs = _causal_conv(
        xs, params["conv_x_w"],
        ctx.local_block(params["conv_x_b"], di_l),
    )
    bc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"])
    Bc, Cc = jnp.split(bc, 2, axis=-1)
    A_log, D, dt_bias = _head_params(params, nh_l, ctx)
    xh = xs.reshape(*xs.shape[:2], nh_l, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + dt_bias)
    A = -jnp.exp(A_log)
    xbar = xh.astype(jnp.float32) * dt[..., None]
    logdA = dt * A
    y, _ = ssd_chunked(xbar, logdA, Bc, Cc,
                       chunk=min(cfg.ssm_chunk, x.shape[1]))
    y = y + D[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], di_l).astype(x.dtype)
    y = y * jax.nn.silu(z)  # gated
    out = y @ params["out_proj"]
    if ctx.active and params["out_proj"].shape[0] != cfg.expand * cfg.d_model:
        out = ctx.psum_scatter(out)  # row-parallel out-projection
    else:
        out = ctx.scatter_seq(out)  # unsharded heads: back to seq block
    return out


def ssm_init_cache(cfg, batch: int, dtype=jnp.float32) -> Dict:
    di = cfg.expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    conv_dim = di + 2 * cfg.d_state
    return {
        "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.d_state), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
    }


def ssm_decode_step(
    params: Dict,
    x: jnp.ndarray,  # (B, 1, d)
    cache: Dict,
    cfg,
) -> Tuple[jnp.ndarray, Dict]:
    di = cfg.expand * cfg.d_model
    hd = cfg.ssm_head_dim
    nh = di // hd
    z = x @ params["zproj"]
    xs = x @ params["xproj"]
    bc = x @ params["bcproj"]
    dt = x @ params["dtproj"]
    conv_in = jnp.concatenate([xs, bc], axis=-1)  # (B,1,di+2N)
    hist = jnp.concatenate(
        [cache["conv"], conv_in.astype(cache["conv"].dtype)], axis=1
    )
    w = jnp.concatenate([params["conv_x_w"], params["conv_bc_w"]], axis=-1)
    b = jnp.concatenate([params["conv_x_b"], params["conv_bc_b"]], axis=-1)
    K = w.shape[0]
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist[:, -K:], w) + b
    )[:, None, :]
    xs, Bc, Cc = jnp.split(conv_out, [di, di + cfg.d_state], axis=-1)
    xh = xs.reshape(xs.shape[0], nh, hd).astype(jnp.float32)
    dt1 = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + params["dt_bias"]
    )  # (B, nh)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt1 * A)  # (B, nh)
    h = cache["h"] * dA[..., None, None] + jnp.einsum(
        "bhd,bn->bhdn", xh * dt1[..., None], Bc[:, 0].astype(jnp.float32)
    )
    y = jnp.einsum("bhdn,bn->bhd", h, Cc[:, 0].astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    new_cache = {"h": h, "conv": hist[:, 1:]}
    return out, new_cache
