"""Attention machinery: GQA, RoPE/M-RoPE, dense & chunked (online-softmax)
variants, sliding windows, ring-buffer decode caches.

Layout conventions:
  q:      (B, S, H,  Dh)
  k, v:   (B, T, Kv, Dh)      H = G · Kv (grouped-query attention)

All softmax math runs in float32 regardless of input dtype.

The chunked path is a pure-JAX online-softmax (flash-style) attention:
``lax.scan`` over KV chunks carrying (max, denom, acc).  For very long
sequences the query axis is additionally chunked with ``lax.map`` so the
largest live score block is (B, Cq, H, Ck) — this is what makes 32k
prefill fit per-chip HBM in the dry-run without a Pallas dependency on
the CPU backend.

Everything here is sequence-length agnostic and always sees the FULL
sequence: under sequence parallelism (``ShardCtx.seq_shard``) the
caller (`transformer._attn_apply`) re-gathers the seq-sharded residual
stream before projecting Q/K/V — attention mixes all positions — and
reduce-scatters after the out-projection, so no function in this
module needs to know about the SP regime.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def local_head_counts(p, head_dim: int) -> Tuple[int, int]:
    """(H, Kv) as seen by THIS shard's projection weights.

    Under tensor parallelism (inside shard_map) the attention weights
    arrive as per-shard column/row blocks, so the head counts must be
    derived from the local shapes, not the config: Q heads shard over
    the model axis while K/V heads replicate whenever ``n_kv_heads``
    does not divide the TP degree (Megatron GQA fallback).  Everything
    downstream (RoPE, GQA grouping, flash/chunked attention) is
    head-count agnostic — it keys off these shapes.
    """
    return p["wq"].shape[-1] // head_dim, p["wk"].shape[-1] // head_dim


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies (head_dim/2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float = 10_000.0,
    sections: Tuple[int, ...] = (),
) -> jnp.ndarray:
    """Rotary embedding.  ``positions``: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the head_dim/2 frequency slots are split into
    ``sections`` (e.g. (16, 24, 24) for Dh=128) driven by the temporal /
    height / width position streams respectively.
    """
    B, S, H, Dh = x.shape
    inv = rope_freqs(Dh, theta)  # (Dh/2,)
    if positions.ndim == 3:  # M-RoPE
        if not sections:
            raise ValueError("M-RoPE positions need mrope sections")
        assert sum(sections) == Dh // 2, (sections, Dh)
        import numpy as np

        sec_id = np.repeat(
            np.arange(len(sections)), np.array(sections)
        )  # (Dh/2,) static map: which stream drives each freq slot
        # angles: (B, S, Dh/2) selecting the right position stream
        pos = positions.astype(jnp.float32)  # (3, B, S)
        pos_per_slot = pos[sec_id]  # (Dh/2, B, S)
        ang = jnp.einsum("dbs,d->bsd", pos_per_slot, inv)
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, Dh/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# masks
# ----------------------------------------------------------------------
def _allowed(
    q_pos: jnp.ndarray,  # (..., S)
    k_pos: jnp.ndarray,  # (..., T)
    causal: bool,
    window: int,
) -> jnp.ndarray:
    """(..., S, T) boolean mask of allowed attention edges."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    ok = k >= 0
    if causal:
        ok &= k <= q
    if window > 0:
        ok &= q - k < window
    return ok


def _softcap(scores: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return cap * jnp.tanh(scores / cap)
    return scores


# ----------------------------------------------------------------------
# dense attention
# ----------------------------------------------------------------------
def dense_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_pos: jnp.ndarray,
    k_pos: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Reference/materializing attention; fine for short sequences."""
    B, S, H, Dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qf = q.astype(jnp.float32).reshape(B, S, Kv, G, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf * scale, kf)
    scores = _softcap(scores, softcap)
    mask = _allowed(q_pos, k_pos, causal, window)  # (B?, S, T)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, vf)
    return out.reshape(B, S, H, Dh).astype(q.dtype)


# ----------------------------------------------------------------------
# chunked (online softmax) attention
# ----------------------------------------------------------------------
def _kv_chunk_scan(
    q: jnp.ndarray,  # (B, S, Kv, G, Dh) f32, pre-scaled
    k: jnp.ndarray,  # (B, T, Kv, Dh)
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # (S,)
    k_pos: jnp.ndarray,  # (T,)
    chunk: int,
    causal: bool,
    window: int,
    softcap: float,
) -> jnp.ndarray:
    B, S, Kv, G, Dh = q.shape
    T = k.shape[1]
    n_chunks = T // chunk

    def body(carry, ic):
        m, l, acc = carry
        start = ic * chunk
        kc = lax.dynamic_slice_in_dim(k, start, chunk, 1).astype(jnp.float32)
        vc = lax.dynamic_slice_in_dim(v, start, chunk, 1).astype(jnp.float32)
        kp = lax.dynamic_slice_in_dim(k_pos, start, chunk, 0)
        s = jnp.einsum("bskgd,btkd->bkgst", q, kc)
        s = _softcap(s, softcap)
        mask = _allowed(q_pos, kp, causal, window)  # (S, chunk)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vc
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Kv, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, S), jnp.float32)
    a0 = jnp.zeros((B, Kv, G, S, Dh), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1)  # (B, S, Kv, G, Dh)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # (S,) shared positions (no batch offsets)
    k_pos: jnp.ndarray,  # (T,)
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_chunk: int = 1024,
    q_chunk: int = 0,
) -> jnp.ndarray:
    """Memory-bounded attention: scan over KV chunks, optional q-chunking."""
    B, S, H, Dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    T = k.shape[1]
    kv_chunk = min(kv_chunk, T)
    if T % kv_chunk:
        raise ValueError(f"T={T} not divisible by kv_chunk={kv_chunk}")
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, S, Kv, G, Dh)

    if q_chunk and S > q_chunk:
        if S % q_chunk:
            raise ValueError(f"S={S} not divisible by q_chunk={q_chunk}")
        nq = S // q_chunk
        qb = qf.reshape(B, nq, q_chunk, Kv, G, Dh)
        qpb = q_pos.reshape(nq, q_chunk)

        def one(args):
            qi, qpi = args  # qi: (B, Cq, Kv, G, Dh)
            return _kv_chunk_scan(
                qi, k, v, qpi, k_pos, kv_chunk, causal, window, softcap,
            )

        outs = lax.map(one, (jnp.moveaxis(qb, 1, 0), qpb))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Kv, G, Dh)
    else:
        out = _kv_chunk_scan(
            qf, k, v, q_pos, k_pos, kv_chunk, causal, window, softcap
        )
    return out.reshape(B, S, H, Dh).astype(q.dtype)


def attention(
    q, k, v, q_pos, k_pos, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    kv_chunk: int = 1024,
    q_chunk_threshold: int = 8192,
    q_chunk: int = 2048,
):
    """Size-dispatching attention used by the transformer blocks."""
    S, T = q.shape[1], k.shape[1]
    if T <= kv_chunk * 2:
        qp = q_pos if q_pos.ndim > 1 else q_pos[None]
        kp = k_pos if k_pos.ndim > 1 else k_pos[None]
        return dense_attention(
            q, k, v, qp, kp, causal=causal, window=window, softcap=softcap
        )
    return chunked_attention(
        q, k, v, q_pos, k_pos,
        causal=causal, window=window, softcap=softcap, kv_chunk=kv_chunk,
        q_chunk=q_chunk if S >= q_chunk_threshold else 0,
    )


# ----------------------------------------------------------------------
# flash attention with custom VJP (beyond-paper perf: EXPERIMENTS.md §Perf)
#
# The autodiff of the kv-chunked scan materializes per-chunk f32 score
# residuals — (B, H, S, T) worth of HBM traffic and temp memory, the
# dominant memory-roofline term of every train cell.  This custom VJP
# saves only (out, logsumexp) and RECOMPUTES scores chunk-by-chunk in
# the backward pass (the standard flash-attention backward, here in
# pure JAX so XLA:TPU fuses it; a Pallas variant would go further).
# ----------------------------------------------------------------------
def _flash_fwd_scan(qf, k, v, q_start, chunk, causal, window, softcap):
    """Like _kv_chunk_scan but also returns the row logsumexp.

    Positions are iota-derived: q rows are q_start..q_start+S-1, kv
    columns 0..T-1 (all our flash uses attend over full prefixes).
    """
    B, S, Kv, G, Dh = qf.shape
    n_chunks = k.shape[1] // chunk
    q_pos = q_start + jnp.arange(S)

    def body(carry, ic):
        m, l, acc = carry
        start = ic * chunk
        kc = lax.dynamic_slice_in_dim(k, start, chunk, 1).astype(jnp.float32)
        vc = lax.dynamic_slice_in_dim(v, start, chunk, 1).astype(jnp.float32)
        kp = start + jnp.arange(chunk)
        s = jnp.einsum("bskgd,btkd->bkgst", qf, kc)
        s = _softcap(s, softcap)
        mask = _allowed(q_pos, kp, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vc)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Kv, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, S), jnp.float32)
    a0 = jnp.zeros((B, Kv, G, S, Dh), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (B,Kv,G,S)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1), jnp.moveaxis(lse, 3, 1)  # (B,S,…)


def _flash_bwd_scan(qf, k, v, out, lse, do, delta, q_start, chunk,
                    causal, window, softcap):
    """Backward: recompute p per kv chunk; accumulate dq, dk, dv."""
    B, S, Kv, G, Dh = qf.shape
    n_chunks = k.shape[1] // chunk
    q_pos = q_start + jnp.arange(S)
    lse_t = jnp.moveaxis(lse, 1, 3)  # (B,Kv,G,S)
    do_t = jnp.moveaxis(do, 1, 3)  # (B,Kv,G,S,Dh)
    delta_t = jnp.moveaxis(delta, 1, 3)  # (B,Kv,G,S)

    def body(dq, ic):
        start = ic * chunk
        kc = lax.dynamic_slice_in_dim(k, start, chunk, 1).astype(jnp.float32)
        vc = lax.dynamic_slice_in_dim(v, start, chunk, 1).astype(jnp.float32)
        kp = start + jnp.arange(chunk)
        s_raw = jnp.einsum("bskgd,btkd->bkgst", qf, kc)
        s = _softcap(s_raw, softcap)
        mask = _allowed(q_pos, kp, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse_t[..., None])  # (B,Kv,G,S,T)
        dv_c = jnp.einsum("bkgst,bkgsd->btkd", p, do_t)
        dp = jnp.einsum("bkgsd,btkd->bkgst", do_t, vc)
        ds = p * (dp - delta_t[..., None])
        if softcap and softcap > 0:
            th = jnp.tanh(s_raw / softcap)
            ds = ds * (1.0 - th * th)
        ds = jnp.where(mask[None, None, None], ds, 0.0)
        dq_c = jnp.einsum("bkgst,btkd->bskgd", ds, kc)
        dk_c = jnp.einsum("bkgst,bskgd->btkd", ds, qf)
        return dq + dq_c, (dk_c, dv_c)

    dq0 = jnp.zeros((B, S, Kv, G, Dh), jnp.float32)
    dq, (dk_chunks, dv_chunks) = lax.scan(
        body, dq0, jnp.arange(n_chunks))
    # dk/dv stacked per chunk: (n_chunks, B, chunk, Kv, Dh)
    T = k.shape[1]
    dk = jnp.moveaxis(dk_chunks, 0, 1).reshape(B, T, Kv, Dh)
    dv = jnp.moveaxis(dv_chunks, 0, 1).reshape(B, T, Kv, Dh)
    return dq, dk, dv


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(
    q, k, v, causal=True, window=0, softcap=0.0, kv_chunk=1024,
    q_chunk=0,
):
    """Memory-O(S) attention with a flash-style custom VJP (GQA-aware).

    Saves only (out, logsumexp); the backward pass recomputes scores
    chunk-by-chunk — no (B,H,S,T) residuals (EXPERIMENTS.md §Perf).
    Assumes q rows are positions 0..S-1 over kv columns 0..T-1 with
    S == T (training/prefill self-attention).
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, window, softcap,
                             kv_chunk, q_chunk)
    return out


def _scaled(q):
    Dh = q.shape[-1]
    return q.astype(jnp.float32) / jnp.sqrt(Dh).astype(jnp.float32)


def _flash_fwd_impl(q, k, v, causal, window, softcap, kv_chunk, q_chunk):
    B, S, H, Dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qf = _scaled(q).reshape(B, S, Kv, G, Dh)
    kv_chunk = min(kv_chunk, k.shape[1])
    if k.shape[1] % kv_chunk:
        raise ValueError(
            f"flash attention needs T % kv_chunk == 0, got "
            f"T={k.shape[1]}, kv_chunk={kv_chunk}"
        )
    if q_chunk and S > q_chunk:
        nq = S // q_chunk
        qb = jnp.moveaxis(qf.reshape(B, nq, q_chunk, Kv, G, Dh), 1, 0)

        def one(args):
            qi, iq = args
            return _flash_fwd_scan(qi, k, v, iq * q_chunk, kv_chunk,
                                   causal, window, softcap)

        outs, lses = lax.map(one, (qb, jnp.arange(nq)))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Kv, G, Dh)
        lse = jnp.moveaxis(lses, 0, 1).reshape(B, S, Kv, G)
    else:
        out, lse = _flash_fwd_scan(qf, k, v, 0, kv_chunk, causal,
                                   window, softcap)
        lse = lse.reshape(B, S, Kv, G)
        out = out.reshape(B, S, Kv, G, Dh)
    return out.reshape(B, S, H, Dh).astype(q.dtype), lse


def _flash_vjp_fwd(q, k, v, causal, window, softcap, kv_chunk, q_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, softcap,
                               kv_chunk, q_chunk)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, softcap, kv_chunk, q_chunk, res, g):
    q, k, v, out, lse = res
    B, S, H, Dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    kv_chunk = min(kv_chunk, k.shape[1])
    qf = _scaled(q).reshape(B, S, Kv, G, Dh)
    do = g.astype(jnp.float32).reshape(B, S, Kv, G, Dh)
    of = out.astype(jnp.float32).reshape(B, S, Kv, G, Dh)
    delta = jnp.sum(do * of, axis=-1)  # (B,S,Kv,G)

    if q_chunk and S > q_chunk:
        nq = S // q_chunk

        def reshuf(x):
            return jnp.moveaxis(
                x.reshape((B, nq, q_chunk) + x.shape[2:]), 1, 0)

        def one(args):
            qi, oi, doi, li, di, iq = args
            return _flash_bwd_scan(qi, k, v, oi, li, doi, di,
                                   iq * q_chunk, kv_chunk, causal,
                                   window, softcap)

        dqs, dks, dvs = lax.map(
            one,
            (reshuf(qf), reshuf(of), reshuf(do),
             reshuf(lse.reshape(B, S, Kv, G)),
             reshuf(delta), jnp.arange(nq)),
        )
        dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, Kv, G, Dh)
        dk = dks.sum(axis=0)
        dv = dvs.sum(axis=0)
    else:
        dq, dk, dv = _flash_bwd_scan(
            qf, k, v, of, lse.reshape(B, S, Kv, G), do, delta, 0,
            kv_chunk, causal, window, softcap,
        )
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    dq = (dq * scale).reshape(B, S, H, Dh).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ----------------------------------------------------------------------
# decode (single new token against a cache)
# ----------------------------------------------------------------------
def ring_slot_positions(
    cache_size: int, length: jnp.ndarray, window: int
) -> jnp.ndarray:
    """Absolute position held in each ring-buffer slot.

    Slot s holds position p = s + w·⌊(L−1−s)/w⌋ (negative ⇒ empty).
    For full (non-ring) caches pass window = cache_size.
    """
    s = jnp.arange(cache_size)
    return s + window * ((length - 1 - s) // window)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, Dh) — rope already applied
    k_cache: jnp.ndarray,  # (B, C, Kv, Dh)
    v_cache: jnp.ndarray,
    q_pos: jnp.ndarray,  # scalar current position (= length − 1)
    k_pos: jnp.ndarray,  # (C,) absolute positions per slot
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    B, _, H, Dh = q.shape
    Kv = k_cache.shape[2]
    G = H // Kv
    qf = q.astype(jnp.float32).reshape(B, Kv, G, Dh)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qf * scale, k_cache.astype(jnp.float32))
    s = _softcap(s, softcap)
    ok = k_pos >= 0
    ok &= k_pos <= q_pos
    if window > 0:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, Dh).astype(q.dtype)
