"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Gated linear recurrence:
    r_t = σ(y_t W_a + b_a)              (recurrence gate)
    i_t = σ(y_t W_x + b_x)              (input gate)
    a_t = a^{c·r_t},  a = σ(Λ)          (per-channel learned decay, c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ y_t)

Being a first-order linear recurrence, training/prefill uses
``lax.associative_scan`` (log-depth — TPU-friendly; this is the
hardware adaptation of the GPU "linear scan kernel" in the Griffin
paper).  Decode is the O(1) update.

The surrounding block (as in Griffin): two width-``r`` branches — a
GeLU gate branch and a conv1d(4)→RG-LRU branch — merged multiplicatively
then projected back to d_model.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import NULL_CTX

_C = 8.0
_MAX_SQRT_GRAD = 1000.0


def init_rglru_block(rng, d: int, r: int, d_conv: int, dtype) -> Dict:
    ks = jax.random.split(rng, 7)
    s = 0.02
    return {
        "w_gate": (jax.random.normal(ks[0], (d, r)) * s).astype(dtype),
        "w_lin": (jax.random.normal(ks[1], (d, r)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (d_conv, r)) * s).astype(dtype),
        "conv_b": jnp.zeros((r,), dtype),
        "w_a": (jax.random.normal(ks[3], (r, r)) * s).astype(dtype),
        "b_a": jnp.zeros((r,), jnp.float32),
        "w_x": (jax.random.normal(ks[4], (r, r)) * s).astype(dtype),
        "b_x": jnp.zeros((r,), jnp.float32),
        # Λ init so that a = σ(Λ) ∈ [0.9, 0.999] as in the paper
        "lam": jnp.log(
            jnp.linspace(0.9, 0.999, r) / (1 - jnp.linspace(0.9, 0.999, r))
        ).astype(jnp.float32),
        "w_out": (jax.random.normal(ks[5], (r, d)) * s).astype(dtype),
    }


def _gates(params, y, ctx=NULL_CTX):
    """Recurrence/input gates on ``y``.

    TP (ctx active): ``y`` carries this shard's block of the recurrence
    width and ``w_a``/``w_x`` are row-parallel — one psum restores the
    full pre-activations, which are then re-sliced to the local block so
    the elementwise recurrence stays shard-local.
    """
    yf = y.astype(jnp.float32)
    r_local = y.shape[-1]
    r_full = params["w_a"].shape[1]
    pre_a = yf @ params["w_a"].astype(jnp.float32)
    pre_x = yf @ params["w_x"].astype(jnp.float32)
    if ctx.active and params["w_a"].shape[0] != r_full:
        # row-parallel gates: one psum for both pre-activation stacks
        pre_a, pre_x = ctx.psum(jnp.stack([pre_a, pre_x]))
    rgate = jax.nn.sigmoid(
        ctx.local_block(pre_a + params["b_a"], r_local)
    )
    igate = jax.nn.sigmoid(
        ctx.local_block(pre_x + params["b_x"], r_local)
    )
    lam = ctx.local_block(params["lam"], r_local)
    log_a = -_C * rgate * jax.nn.softplus(lam)  # log a_t ≤ 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12))
    b = mult * igate * yf
    return a, b


def rglru_scan(params, y: jnp.ndarray, h0=None,
               ctx=NULL_CTX) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence RG-LRU via associative scan. y: (B, S, r)."""
    a, b = _gates(params, y, ctx)
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, rgt):
        al, bl = l
        ar, br = rgt
        return al * ar, ar * bl + br

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(y.dtype), h[:, -1]


def rglru_step(params, y1: jnp.ndarray, h: jnp.ndarray):
    """Single decode step. y1: (B, 1, r); h: (B, r)."""
    a, b = _gates(params, y1)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new.astype(y1.dtype)[:, None, :], h_new


def _causal_conv(seq, w, b):
    K = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(seq)
    for k in range(K):
        out = out + pad[:, k : k + seq.shape[1], :] * w[k]
    return out + b


def rglru_block_forward(params, x: jnp.ndarray, cfg,
                        ctx=NULL_CTX) -> jnp.ndarray:
    """Full recurrent block (train/prefill). x: (B, S, d).

    TP: gate/lin branches are column-parallel over the recurrence
    width, ``w_out`` row-parallel (psum restores the full d output).
    SP (ctx.sp): the linear recurrence is sequential in seq — the
    block gathers the full sequence before the scan (ctx-driven
    fallback, like the SSD block) and the row-parallel ``w_out``
    reduce-scatters back to the local seq block.
    """
    x = ctx.gather_seq(x)  # gather-before-scan: the scan needs all of S
    gate = jax.nn.gelu(x @ params["w_gate"])
    y = x @ params["w_lin"]
    r_local = y.shape[-1]
    y = _causal_conv(y, params["conv_w"],
                     ctx.local_block(params["conv_b"], r_local))
    h, _ = rglru_scan(params, y, ctx=ctx)
    out = (gate * h) @ params["w_out"]
    if ctx.active and params["w_out"].shape[0] != (cfg.lru_width
                                                  or cfg.d_model):
        out = ctx.psum_scatter(out)  # row-parallel out-projection
    else:
        out = ctx.scatter_seq(out)
    return out


def rglru_init_cache(cfg, batch: int, dtype=jnp.float32) -> Dict:
    r = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, r), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, r), dtype),
    }


def rglru_block_step(params, x1: jnp.ndarray, cache: Dict, cfg):
    """Decode step. x1: (B, 1, d)."""
    gate = jax.nn.gelu(x1 @ params["w_gate"])
    y = x1 @ params["w_lin"]
    hist = jnp.concatenate(
        [cache["conv"], y.astype(cache["conv"].dtype)], axis=1
    )
    K = params["conv_w"].shape[0]
    y = (jnp.einsum("bkc,kc->bc", hist[:, -K:], params["conv_w"])
         + params["conv_b"])[:, None, :]
    hs, h_new = rglru_step(params, y.astype(x1.dtype), cache["h"])
    out = (gate * hs) @ params["w_out"]
    return out, {"h": h_new, "conv": hist[:, 1:]}
