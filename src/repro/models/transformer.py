"""Pure-JAX model assembly for all assigned architectures.

Design:
  * params are nested dicts of jnp arrays; all weight matrices are 2-D
    (heads fused as H·Dh) so tensor-parallel sharding divides evenly on
    every assigned config,
  * the layer stack is grouped by the config's ``block_pattern`` period
    and scanned with ``lax.scan`` (stacked params ⇒ compact HLO — a 62-
    layer gemma3 lowers as 10 scanned groups of 6 + 2 unrolled layers),
  * ``jax.checkpoint`` (remat) wraps each scanned group,
  * layer kinds: "global" / "local" attention, "ssm" (Mamba-2 SSD),
    "recurrent" (RG-LRU); optional MoE replaces the dense FFN,
  * encoder–decoder (whisper) adds a bidirectional encoder stack and
    cross-attention in every decoder layer,
  * decode paths carry explicit caches (ring buffers for local layers).

Public entry points:
  init_params, forward, loss_and_metrics,
  init_cache, prefill, decode_step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import (
    NULL_CTX,
    ShardCtx,
    anchor_activations,
    anchor_embed,
    anchor_logits,
    anchor_replicated,
)
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib

PyTree = Any

#: weight of the MoE load-balancing aux loss in the training objective —
#: the dist train step reuses this to decode the aux gradient with
#: uniform weights (separate psum from the λ-weighted data term)
AUX_WEIGHT = 0.01


# ----------------------------------------------------------------------
# normalization
# ----------------------------------------------------------------------
def _init_norm(cfg: ModelConfig, d: int) -> Dict:
    if cfg.norm == "layer":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def _norm(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * lax.rsqrt(ms + 1e-6) * p["scale"]
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# layer init
# ----------------------------------------------------------------------
def _init_attn(rng, cfg: ModelConfig, cross: bool = False) -> Dict:
    d, H, Kv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    s = 0.02
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": (jax.random.normal(ks[0], (d, H * Dh)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, Kv * Dh)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, Kv * Dh)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (H * Dh, d)) * s).astype(dt),
    }


def _init_mlp(rng, cfg: ModelConfig) -> Dict:
    d, ff = cfg.d_model, (cfg.d_ff_dense or cfg.d_ff)
    dt = jnp.dtype(cfg.param_dtype)
    s = 0.02
    if cfg.mlp == "swiglu":
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "wg": (jax.random.normal(k1, (d, ff)) * s).astype(dt),
            "wu": (jax.random.normal(k2, (d, ff)) * s).astype(dt),
            "wd": (jax.random.normal(k3, (ff, d)) * s).astype(dt),
        }
    k1, k2 = jax.random.split(rng, 2)
    return {
        "w1": (jax.random.normal(k1, (d, ff)) * s).astype(dt),
        "w2": (jax.random.normal(k2, (ff, d)) * s).astype(dt),
    }


def _init_layer(rng, cfg: ModelConfig, kind: str, cross: bool = False,
                moe: Optional[bool] = None) -> Dict:
    ks = jax.random.split(rng, 6)
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    if moe is None:
        moe = cfg.is_moe
    p: Dict[str, Any] = {"norm1": _init_norm(cfg, d)}
    if kind in ("global", "local", "enc"):
        p["attn"] = _init_attn(ks[0], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm_lib.init_ssm(
            ks[0], d, cfg.expand, cfg.d_state, cfg.d_conv,
            cfg.ssm_head_dim, dt,
        )
    elif kind == "recurrent":
        p["rglru"] = rglru_lib.init_rglru_block(
            ks[0], d, cfg.lru_width or d, cfg.d_conv, dt
        )
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cross:
        p["norm_x"] = _init_norm(cfg, d)
        p["xattn"] = _init_attn(ks[1], cfg)
    if cfg.d_ff > 0 and kind != "ssm":
        p["norm2"] = _init_norm(cfg, d)
        if moe:
            p["moe"] = moe_lib.init_moe(
                ks[2], d, cfg.d_ff, cfg.n_experts, cfg.n_shared_experts, dt
            )
        else:
            p["mlp"] = _init_mlp(ks[2], cfg)
    return p


def init_params(rng, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(rng, 8)
    d, V = cfg.d_model, cfg.vocab
    dt = jnp.dtype(cfg.param_dtype)
    params: Dict[str, Any] = {
        "embed": {
            "table": (jax.random.normal(ks[0], (V, d)) * 0.02).astype(dt)
        },
        "final_norm": _init_norm(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["head"] = {
            "w": (jax.random.normal(ks[1], (d, V)) * 0.02).astype(dt)
        }
    P = len(cfg.block_pattern)
    n_groups, n_rest = cfg.n_layers // P, cfg.n_layers % P
    cross = cfg.is_encdec

    def stack_layers(rng, count, kind, moe=None):
        lrngs = jax.random.split(rng, max(count, 1))
        layers = [
            _init_layer(lrngs[i], cfg, kind, cross, moe=moe)
            for i in range(count)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    groups: Dict[str, Any] = {}
    for k in range(P):
        groups[f"p{k}"] = stack_layers(
            jax.random.fold_in(ks[2], k), n_groups, cfg.block_pattern[k],
            moe=cfg.moe_at(k),
        )
    params["groups"] = groups
    rest: Dict[str, Any] = {}
    for k in range(n_rest):
        rest[f"r{k}"] = _init_layer(
            jax.random.fold_in(ks[3], k), cfg, cfg.block_pattern[k], cross,
            moe=cfg.moe_at(k),
        )
    if rest:
        params["rest"] = rest
    if cfg.is_encdec:
        enc: Dict[str, Any] = {
            "enc_norm": _init_norm(cfg, d),
        }
        enc["groups"] = {
            "p0": stack_layers(ks[4], cfg.n_enc_layers, "enc")
        }
        params["encoder"] = enc
    return params


# ----------------------------------------------------------------------
# layer application (full sequence)
# ----------------------------------------------------------------------
def _split_heads(x, n, Dh):
    return x.reshape(*x.shape[:-1], n, Dh)


def _attn_apply(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, kind: str,
    positions: jnp.ndarray,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    kv_positions: Optional[jnp.ndarray] = None,
    ctx: ShardCtx = NULL_CTX,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Returns (output, (k, v) for caching). kv_override ⇒ cross-attn.

    TP (ctx active): in-projections are column-parallel (this shard's
    head block — K/V replicate when n_kv_heads doesn't divide tp), the
    out-projection is row-parallel, finished by one psum over "model".
    SP (ctx.sp): ``x`` arrives as the local seq block — attention mixes
    the whole sequence, so the block re-gathers seq up front and the
    row-parallel finish reduce-scatters back to the local block.
    """
    x = ctx.gather_seq(x)
    B, S, d = x.shape
    Dh = cfg.head_dim
    H, Kv = attn_lib.local_head_counts(p, Dh)
    # replicated-KV GQA fallback (TP with n_kv_heads ∤ tp): every shard
    # computes all KV heads but its Q block lives inside ONE KV group
    # (validate_tp guarantees tp % n_kv_heads == 0) — slice that head so
    # the local Q→KV pairing matches the unsharded model.
    kv_slice = (ctx.active and H != cfg.n_heads and Kv == cfg.n_kv_heads
                and Kv > 1)
    q = _split_heads(x @ p["wq"], H, Dh)
    if kv_override is None:
        k = _split_heads(x @ p["wk"], Kv, Dh)
        v = _split_heads(x @ p["wv"], Kv, Dh)
        if kv_slice:
            kv_head = ctx.axis_index() * Kv // ctx.tp
            k = lax.dynamic_slice_in_dim(k, kv_head, 1, axis=2)
            v = lax.dynamic_slice_in_dim(v, kv_head, 1, axis=2)
            Kv = 1
        k_pos_flat = positions[0] if positions.ndim == 3 else positions[0:1]
        if kind != "enc" or cfg.rope_theta > 0:
            q = attn_lib.apply_rope(
                q, positions, cfg.rope_theta, cfg.mrope_sections
            )
            k = attn_lib.apply_rope(
                k, positions, cfg.rope_theta, cfg.mrope_sections
            )
        kv, kvp = (k, v), None
    else:
        k, v = kv_override
        if kv_slice:
            kv_head = ctx.axis_index() * Kv // ctx.tp
            k = lax.dynamic_slice_in_dim(k, kv_head, 1, axis=2)
            v = lax.dynamic_slice_in_dim(v, kv_head, 1, axis=2)
            Kv = 1
        kv, kvp = (k, v), kv_positions
    causal = kind != "enc" and kv_override is None
    window = cfg.window if kind == "local" else 0
    if (cfg.flash and kv_override is None and k.shape[1] == S
            and S % min(cfg.attn_chunk, S) == 0):
        # §Perf: custom-VJP flash attention (self-attention, arange
        # positions) — no (B,H,S,T) residuals saved for backward.
        out = attn_lib.flash_attention(
            q, k, v, causal, window, cfg.logit_softcap,
            cfg.attn_chunk, cfg.q_chunk if S >= 8192 else 0,
        )
    else:
        # flat positions for the chunked path (shared across batch)
        qp = positions[0, 0] if positions.ndim == 3 else positions[0]
        kp = qp if kv_override is None else kv_positions
        out = attn_lib.attention(
            q, k, v, qp, kp,
            causal=causal, window=window, softcap=cfg.logit_softcap,
            kv_chunk=cfg.attn_chunk, q_chunk=cfg.q_chunk,
        )
    out = out.reshape(B, S, H * Dh) @ p["wo"]
    if ctx.active and H != cfg.n_heads:
        out = ctx.psum_scatter(out)  # row-parallel out-projection
    else:
        out = ctx.scatter_seq(out)  # unsharded attn: back to seq block
    return out, kv


def _mlp_apply(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
               ctx: ShardCtx = NULL_CTX):
    # SP: the column-parallel up-projections want the full sequence
    # (each shard computes its ff block over every token)
    x = ctx.gather_seq(x)
    if cfg.mlp == "swiglu" and "wg" in p:
        out = (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
        sharded = p["wd"].shape[0] != (cfg.d_ff_dense or cfg.d_ff)
    else:
        out = jax.nn.gelu(x @ p["w1"]) @ p["w2"]
        sharded = p["w2"].shape[0] != (cfg.d_ff_dense or cfg.d_ff)
    if ctx.active and sharded:
        out = ctx.psum_scatter(out)  # row-parallel down-projection
    else:
        out = ctx.scatter_seq(out)
    return out


def _ckpt_name(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Tag post-all-reduce block outputs for the remat policy (§Perf)."""
    if cfg.remat_policy == "save_block_outputs":
        from jax.ad_checkpoint import checkpoint_name

        return checkpoint_name(x, "block_out")
    return x


def _remat_wrap(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "save_block_outputs":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "block_out"),
        )
    return jax.checkpoint(fn)


def _layer_apply(
    p: Dict, x: jnp.ndarray, kind: str, cfg: ModelConfig,
    positions: jnp.ndarray,
    enc_out: Optional[jnp.ndarray] = None,
    enc_positions: Optional[jnp.ndarray] = None,
    ctx: ShardCtx = NULL_CTX,
) -> Tuple[jnp.ndarray, PyTree, jnp.ndarray]:
    """Returns (x_out, cache_entry, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(p["norm1"], x)
    cache_entry: PyTree = ()
    if kind in ("global", "local", "enc"):
        out, (k, v) = _attn_apply(p["attn"], h, cfg, kind, positions,
                                  ctx=ctx)
        cache_entry = {
            "k": k.reshape(*k.shape[:2], -1),
            "v": v.reshape(*v.shape[:2], -1),
        }
    elif kind == "ssm":
        out = ssm_lib.ssm_forward(p["ssm"], h, cfg, ctx=ctx)
    elif kind == "recurrent":
        out = rglru_lib.rglru_block_forward(p["rglru"], h, cfg, ctx=ctx)
    else:
        raise ValueError(kind)
    x = x + _ckpt_name(out, cfg)
    if "xattn" in p and enc_out is not None:
        h = _norm(p["norm_x"], x)
        kv_loc = attn_lib.local_head_counts(p["xattn"], cfg.head_dim)[1]
        out, _ = _attn_apply(
            p["xattn"], h, cfg, "cross", positions,
            kv_override=(
                _split_heads(enc_out @ p["xattn"]["wk"], kv_loc,
                             cfg.head_dim),
                _split_heads(enc_out @ p["xattn"]["wv"], kv_loc,
                             cfg.head_dim),
            ),
            kv_positions=enc_positions,
            ctx=ctx,
        )
        x = x + out
    if "norm2" in p:
        h = _norm(p["norm2"], x)
        if "moe" in p:
            out, aux = moe_lib.moe_ffn(
                p["moe"], h, cfg.top_k, cfg.capacity_factor,
                ctx=ctx, shared_width=cfg.n_shared_experts * cfg.d_ff,
                n_experts=cfg.n_experts,
            )
        else:
            out = _mlp_apply(p["mlp"], h, cfg, ctx=ctx)
        x = x + _ckpt_name(out, cfg)
    return x, cache_entry, aux


# ----------------------------------------------------------------------
# full forward (train / prefill)
# ----------------------------------------------------------------------
def cast_params(params: PyTree, cfg: ModelConfig) -> PyTree:
    """One bf16 working copy of the weights (norm scales stay f32).

    No-op when param_dtype == compute dtype (the big-model configs).
    """
    tgt = jnp.dtype(cfg.dtype)

    def cast(a):
        if a.ndim >= 2 and a.dtype == jnp.float32 and a.dtype != tgt:
            return a.astype(tgt)
        return a

    return jax.tree.map(cast, params)


def _embed(params, cfg, tokens, ctx: ShardCtx = NULL_CTX):
    # Gathers from a sharded table hit an SPMD-partitioner verifier bug
    # (invalid dynamic-slice in the "last resort" path).  The table is
    # stored d-sharded; we all-gather a bf16 working copy at the use
    # site — the gather is then trivially partitionable on the batch
    # axis and the all-gather hoists out of the microbatch loop.
    table = params["embed"]["table"].astype(jnp.dtype(cfg.dtype))
    if ctx.active and table.shape[-1] != cfg.d_model:
        # TP: gather the per-shard embedding slices back to full width
        # (the transpose is a reduce-scatter ⇒ exact local table grads)
        return ctx.all_gather(table[tokens], axis=-1)
    x = anchor_replicated(table)[tokens]
    return anchor_embed(x)


def _matmul_f32(x, w, cfg):
    # accumulate the vocab matmul in f32 without materializing f32 weights
    return jax.lax.dot_general(
        x.astype(jnp.dtype(cfg.dtype)), w,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _unembed(params, cfg, x, ctx: ShardCtx = NULL_CTX):
    x = _norm(params["final_norm"], x)
    # SP: the final norm ran on the local seq block; the vocab-parallel
    # head wants the full sequence back (the CE decode below then still
    # spends exactly ONE psum over "model" — the count is unchanged)
    x = ctx.gather_seq(x)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].T
        if ctx.active and w.shape[0] != cfg.d_model:
            # TP, tied head: the transposed table is row-parallel —
            # slice x to this shard's d-block and psum the partial
            # logits (full-vocab logits, ordinary cross-entropy after)
            return ctx.psum(
                _matmul_f32(ctx.local_block(x, w.shape[0]), w, cfg)
            )
        return _matmul_f32(x, w, cfg)
    # untied head (d, V): column-parallel ⇒ vocab-parallel local logits;
    # the cross-entropy decodes them with one fused psum (see
    # loss_and_metrics)
    return _matmul_f32(x, params["head"]["w"], cfg)


def _run_encoder(params, cfg, frames, ctx: ShardCtx = NULL_CTX):
    """Whisper encoder over precomputed frontend frames (B, T_enc, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(x.shape[1])[None].repeat(x.shape[0], 0)

    def body(x, lp):
        x, _, _ = _layer_apply(lp, x, "enc", cfg, pos, ctx=ctx)
        return x, None

    body = _remat_wrap(body, cfg)
    x, _ = lax.scan(body, x, params["encoder"]["groups"]["p0"])
    return _norm(params["encoder"]["enc_norm"], x)


# ----------------------------------------------------------------------
# forward pieces — shared by the monolithic forward() and the pipelined
# dist train step (launch.steps), which runs them per stage/microbatch
# ----------------------------------------------------------------------
def embed_tokens(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S)
    positions: Optional[jnp.ndarray] = None,
    visual_embeds: Optional[jnp.ndarray] = None,
    ctx: ShardCtx = NULL_CTX,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Embedding + VLM frontend + default positions + SP seq scatter.

    ``params`` must already be cast (:func:`cast_params`).  Returns
    ``(x, positions)`` with ``x`` in the residual-stream layout the
    block stack consumes (seq-sharded under SP) and ``positions``
    full-length — blocks gather before attending.
    """
    B, S = tokens.shape
    x = _embed(params, cfg, tokens, ctx)
    if visual_embeds is not None:
        # VLM stub: frontend embeddings replace the first n_vis positions
        n_vis = visual_embeds.shape[1]
        x = jnp.concatenate(
            [visual_embeds.astype(x.dtype), x[:, n_vis:]], axis=1
        )
    if positions is None:
        positions = jnp.arange(S)[None].repeat(B, 0)
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions, (3, B, S))
    # SP: the residual stream between blocks lives seq-sharded over
    # "model" — slice after the seq-global embedding/frontend work
    x = ctx.scatter_seq(x)
    return x, positions


def encode_frames(
    params: PyTree, cfg: ModelConfig, enc_frames: jnp.ndarray,
    ctx: ShardCtx = NULL_CTX,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Whisper encoder pass → ``(enc_out, enc_positions)``.

    ``params`` must already be cast.  The encoder stays out of the SP
    regime: enc_len need not divide tp and cross-attention consumes the
    full encoder sequence.
    """
    enc_out = _run_encoder(params, cfg, enc_frames, ctx.no_sp())
    return enc_out, jnp.arange(enc_out.shape[1])


def _apply_groups(
    group_params: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    enc_out: Optional[jnp.ndarray] = None,
    enc_pos: Optional[jnp.ndarray] = None,
    ctx: ShardCtx = NULL_CTX,
    return_cache: bool = False,
) -> Tuple[jnp.ndarray, Dict, jnp.ndarray]:
    """Scan the stacked layer groups over ``x``.

    ``group_params`` may be the full ``params["groups"]`` stack or a
    stage-local slice of it (pipeline parallelism) — the scan length is
    whatever leading dim the stack carries.  Returns
    ``(x, caches, aux_sum)``.
    """
    period = len(cfg.block_pattern)

    def group_body(x, gp):
        caches = {}
        aux_g = jnp.zeros((), jnp.float32)
        for k in range(period):
            kind = cfg.block_pattern[k]
            x, ce, aux = _layer_apply(
                gp[f"p{k}"], x, kind, cfg, positions,
                enc_out, enc_pos, ctx=ctx,
            )
            x = anchor_activations(x)
            # only the prefill path wants K/V back; the loss path must
            # not stack full-seq cache entries through the scan's ys
            caches[f"p{k}"] = ce if return_cache else ()
            aux_g = aux_g + aux
        return x, (caches, aux_g)

    body = _remat_wrap(group_body, cfg)
    x, (g_caches, g_aux) = lax.scan(body, x, group_params)
    return x, g_caches, g_aux.sum()


def _apply_rest(
    params: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    enc_out: Optional[jnp.ndarray] = None,
    enc_pos: Optional[jnp.ndarray] = None,
    ctx: ShardCtx = NULL_CTX,
) -> Tuple[jnp.ndarray, Dict, jnp.ndarray]:
    """The unscanned remainder layers (``n_layers % period``)."""
    rest_caches: Dict = {}
    aux_total = jnp.zeros((), jnp.float32)
    for k in range(cfg.n_layers % len(cfg.block_pattern)):
        kind = cfg.block_pattern[k]
        x, ce, aux = _layer_apply(
            params["rest"][f"r{k}"], x, kind, cfg, positions,
            enc_out, enc_pos, ctx=ctx,
        )
        rest_caches[f"r{k}"] = ce
        aux_total = aux_total + aux
    return x, rest_caches, aux_total


def _ce_nll(
    logits: jnp.ndarray, targets: jnp.ndarray, cfg: ModelConfig,
    ctx: ShardCtx = NULL_CTX,
) -> jnp.ndarray:
    """Per-token negative log-likelihood (B, S).

    TP (ctx active, untied head): logits arrive vocab-parallel and the
    decode spends exactly ONE fused psum over the model axis (logsumexp
    partials + target log-likelihood together).
    """
    V = logits.shape[-1]
    if ctx.active and V != cfg.vocab:
        # vocab-parallel CE: max-shift via pmax (stop_gradient — the
        # shift cancels analytically), then one psum carries both the
        # local exp-sums and this shard's masked target logit
        m = ctx.pmax(lax.stop_gradient(jnp.max(logits, axis=-1)))
        s = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
        v0 = ctx.axis_index() * V
        tloc = targets - v0
        valid = (tloc >= 0) & (tloc < V)
        ll = jnp.take_along_axis(
            logits, jnp.clip(tloc, 0, V - 1)[..., None], axis=-1
        )[..., 0]
        ll = jnp.where(valid, ll, 0.0)
        s, ll = ctx.psum(jnp.stack([s, ll]))
        lse = jnp.log(s) + m
    else:
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, targets[..., None], axis=-1
        )[..., 0]
    return lse - ll


def head_loss_terms(
    params: PyTree,
    cfg: ModelConfig,
    x: jnp.ndarray,
    targets: jnp.ndarray,
    weights: Optional[jnp.ndarray],
    positions: jnp.ndarray,
    enc_out: Optional[jnp.ndarray] = None,
    enc_pos: Optional[jnp.ndarray] = None,
    ctx: ShardCtx = NULL_CTX,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Rest layers + unembed + weighted CE on a block-stack output.

    The pipelined train step runs this on the LAST stage only (masked
    elsewhere); ``params`` must already be cast.  Returns the un-
    normalized terms ``(Σ nll·w, Σ w, aux_rest)`` so the caller picks
    the denominator (the coded paths use the fixed batch "denom").
    """
    x, _, aux = _apply_rest(params, cfg, x, positions, enc_out, enc_pos,
                            ctx=ctx)
    logits = anchor_logits(_unembed(params, cfg, x, ctx))
    nll = _ce_nll(logits, targets, cfg, ctx)
    w = weights if weights is not None else jnp.ones_like(nll)
    return (nll * w).sum(), w.sum(), aux


def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S)
    positions: Optional[jnp.ndarray] = None,  # (B,S) or (3,B,S)
    enc_frames: Optional[jnp.ndarray] = None,  # (B, T_enc, d) whisper stub
    visual_embeds: Optional[jnp.ndarray] = None,  # (B, n_vis, d) vlm stub
    return_cache: bool = False,
    last_only: bool = False,  # unembed only the final position (prefill)
    ctx: Optional[ShardCtx] = None,  # TP execution seam (dist path)
) -> Any:
    """Full-sequence forward.  Returns logits (B,S,V) [+ cache, aux]."""
    ctx = ctx or NULL_CTX
    params = cast_params(params, cfg)
    enc_out = enc_pos = None
    if cfg.is_encdec:
        if enc_frames is None:
            raise ValueError("encoder-decoder model needs enc_frames")
        enc_out, enc_pos = encode_frames(params, cfg, enc_frames, ctx)
    x, positions = embed_tokens(
        params, cfg, tokens, positions=positions,
        visual_embeds=visual_embeds, ctx=ctx,
    )
    x, g_caches, g_aux = _apply_groups(
        params["groups"], cfg, x, positions, enc_out, enc_pos,
        ctx=ctx, return_cache=return_cache,
    )
    x, rest_caches, rest_aux = _apply_rest(
        params, cfg, x, positions, enc_out, enc_pos, ctx=ctx
    )
    aux_total = g_aux + rest_aux
    if last_only:
        # the final position lives on the last SP shard — re-gather
        # first (serve paths run with ctx inactive; this keeps the SP
        # regime correct for any caller)
        x = ctx.gather_seq(x)[:, -1:]
        ctx = ctx.no_sp()
    logits = anchor_logits(_unembed(params, cfg, x, ctx))
    if return_cache:
        cache = {"groups": g_caches, "rest": rest_caches}
        return logits, cache, aux_total
    return logits, aux_total


def loss_and_metrics(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    aux_weight: float = AUX_WEIGHT,
    ctx: Optional[ShardCtx] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Weighted token cross-entropy.

    ``batch["weights"]`` (B,S) carries padding masks AND the HGC coding
    coefficients (per-example coded weights — see DESIGN.md §3): the
    gradient of this loss IS the worker's encoded message ``G_ij``.

    TP (ctx active, untied head): logits arrive vocab-parallel and the
    cross-entropy decodes them with exactly ONE fused psum over the
    model axis (logsumexp partials + target log-likelihood together) —
    the loss is then replicated across model shards, so the caller's
    pod/data reductions must NOT psum it over "model" again.
    """
    ctx = ctx or NULL_CTX
    logits, aux = forward(
        params, cfg, batch["tokens"],
        positions=batch.get("positions"),
        enc_frames=batch.get("enc_frames"),
        visual_embeds=batch.get("visual_embeds"),
        ctx=ctx,
    )
    nll = _ce_nll(logits, batch["targets"], cfg, ctx)
    w = batch.get("weights")
    if w is None:
        w = jnp.ones_like(nll)
    # "denom": fixed normalizer keeping the loss LINEAR in the weights —
    # required for exact HGC coded aggregation (weights then carry the
    # coding coefficients; the gradient is the coded linear combination).
    denom = batch.get("denom")
    if denom is None:
        denom = jnp.maximum(w.sum(), 1.0)
    loss = (nll * w).sum() / denom
    total = loss + aux_weight * aux
    metrics = {
        "loss": loss,
        "aux_loss": aux,
        "weight_sum": w.sum(),
    }
    return total, metrics


# ----------------------------------------------------------------------
# decode: cache init, prefill, single step
# ----------------------------------------------------------------------
def _cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    if kind == "local" and cfg.window > 0:
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: Optional[str] = None) -> PyTree:
    """Empty decode cache (ring buffers for local layers)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    Kv, Dh = cfg.n_kv_heads, cfg.head_dim
    P = len(cfg.block_pattern)
    n_groups, n_rest = cfg.n_layers // P, cfg.n_layers % P

    def entry(kind, stacked: int = 0):
        if kind in ("global", "local", "enc"):
            C = _cache_len(cfg, kind, max_len)
            shp = (batch, C, Kv * Dh)
            xshp = (batch, cfg.enc_len, Kv * Dh)
            if stacked:
                shp = (stacked,) + shp
                xshp = (stacked,) + xshp
            e = {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}
            if cfg.is_encdec:
                e["xk"] = jnp.zeros(xshp, dt)
                e["xv"] = jnp.zeros(xshp, dt)
            return e
        if kind == "ssm":
            c = ssm_lib.ssm_init_cache(cfg, batch)
            if stacked:
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (stacked,) + a.shape), c
                )
            return c
        if kind == "recurrent":
            c = rglru_lib.rglru_init_cache(cfg, batch)
            if stacked:
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (stacked,) + a.shape), c
                )
            return c
        raise ValueError(kind)

    cache = {
        "groups": {
            f"p{k}": entry(cfg.block_pattern[k], n_groups)
            for k in range(P)
        },
        "rest": {
            f"r{k}": entry(cfg.block_pattern[k]) for k in range(n_rest)
        },
        "length": jnp.zeros((), jnp.int32),
    }
    return cache


def fill_cross_cache(params: PyTree, cfg: ModelConfig,
                     enc_frames: jnp.ndarray, cache: PyTree) -> PyTree:
    """Populate per-decoder-layer cross-attention K/V from the encoder.

    Run once before decode for encoder-decoder models (whisper).
    """
    params = cast_params(params, cfg)
    enc_out = _run_encoder(params, cfg, enc_frames)
    P = len(cfg.block_pattern)

    def proj(layer_p):
        return (enc_out @ layer_p["xattn"]["wk"],
                enc_out @ layer_p["xattn"]["wv"])

    cache = jax.tree.map(lambda a: a, cache)  # shallow copy
    for k in range(P):
        gp = params["groups"][f"p{k}"]
        xk, xv = jax.vmap(proj)(gp)  # stacked over groups
        cache["groups"][f"p{k}"]["xk"] = xk.astype(
            cache["groups"][f"p{k}"]["xk"].dtype)
        cache["groups"][f"p{k}"]["xv"] = xv.astype(
            cache["groups"][f"p{k}"]["xv"].dtype)
    for k in range(cfg.n_layers % P):
        rp = params["rest"][f"r{k}"]
        xk, xv = proj(rp)
        cache["rest"][f"r{k}"]["xk"] = xk.astype(
            cache["rest"][f"r{k}"]["xk"].dtype)
        cache["rest"][f"r{k}"]["xv"] = xv.astype(
            cache["rest"][f"r{k}"]["xv"].dtype)
    return cache


def _decode_layer(
    p: Dict, x1: jnp.ndarray, kind: str, cfg: ModelConfig,
    cache_entry: PyTree, pos: jnp.ndarray,
    use_pallas: bool = False,
) -> Tuple[jnp.ndarray, PyTree]:
    B = x1.shape[0]
    H, Kv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = _norm(p["norm1"], x1)
    if kind in ("global", "local"):
        q = _split_heads(h @ p["attn"]["wq"], H, Dh)
        k = _split_heads(h @ p["attn"]["wk"], Kv, Dh)
        v = _split_heads(h @ p["attn"]["wv"], Kv, Dh)
        posb = jnp.full((B, 1), pos)
        if cfg.mrope_sections:
            posb = jnp.broadcast_to(posb, (3, B, 1))
        q = attn_lib.apply_rope(q, posb, cfg.rope_theta, cfg.mrope_sections)
        k = attn_lib.apply_rope(k, posb, cfg.rope_theta, cfg.mrope_sections)
        C = cache_entry["k"].shape[1]
        window = cfg.window if kind == "local" else 0
        slot = pos % C
        kc = lax.dynamic_update_slice_in_dim(
            cache_entry["k"], k.reshape(B, 1, Kv * Dh).astype(
                cache_entry["k"].dtype), slot, 1)
        vc = lax.dynamic_update_slice_in_dim(
            cache_entry["v"], v.reshape(B, 1, Kv * Dh).astype(
                cache_entry["v"].dtype), slot, 1)
        if use_pallas:
            # fused kernel derives the slot-position vector in VMEM from
            # the ring write pointer (same formula as below)
            from repro.kernels import ops as kernel_ops

            out = kernel_ops.decode_attention(
                q, kc.reshape(B, C, Kv, Dh), vc.reshape(B, C, Kv, Dh),
                pos, window=window, softcap=cfg.logit_softcap,
            )
        else:
            k_pos = attn_lib.ring_slot_positions(
                C, pos + 1, window if window > 0 else C
            )
            out = attn_lib.decode_attention(
                q, kc.reshape(B, C, Kv, Dh), vc.reshape(B, C, Kv, Dh),
                pos, k_pos, window=window, softcap=cfg.logit_softcap,
            )
        out = out.reshape(B, 1, H * Dh) @ p["attn"]["wo"]
        new_entry = dict(cache_entry)
        new_entry.update({"k": kc, "v": vc})
    elif kind == "ssm":
        out, new_entry = ssm_lib.ssm_decode_step(p["ssm"], h, cache_entry, cfg)
    elif kind == "recurrent":
        out, new_entry = rglru_lib.rglru_block_step(
            p["rglru"], h, cache_entry, cfg
        )
    else:
        raise ValueError(kind)
    x1 = x1 + out
    if "xattn" in p and isinstance(cache_entry, dict) and "xk" in cache_entry:
        hx = _norm(p["norm_x"], x1)
        q = _split_heads(hx @ p["xattn"]["wq"], H, Dh)
        Ce = cache_entry["xk"].shape[1]
        out = attn_lib.decode_attention(
            q,
            cache_entry["xk"].reshape(B, Ce, Kv, Dh),
            cache_entry["xv"].reshape(B, Ce, Kv, Dh),
            jnp.asarray(Ce, jnp.int32),  # attend over the whole encoder
            jnp.arange(Ce),
        )
        x1 = x1 + out.reshape(B, 1, H * Dh) @ p["xattn"]["wo"]
    if "norm2" in p:
        h = _norm(p["norm2"], x1)
        if "moe" in p:
            out, _ = moe_lib.moe_ffn(p["moe"], h, cfg.top_k,
                                     cfg.capacity_factor)
        else:
            out = _mlp_apply(p["mlp"], h, cfg)
        x1 = x1 + out
    return x1, new_entry


def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B, 1) int32
    cache: PyTree,
    use_pallas: Optional[bool] = None,
) -> Tuple[jnp.ndarray, PyTree]:
    """One decode step against the cache; returns (logits (B,V), cache).

    ``use_pallas=None`` auto-selects the fused ring-buffer decode-
    attention kernel on TPU (``kernels.decode_attention``) and the XLA
    path elsewhere; True forces the kernel (interpret mode off-TPU —
    the parity configuration tests/test_decode_attention.py pins).
    Only the self-attention ring path switches; ssm / recurrent /
    cross-attention layers are unaffected.
    """
    if use_pallas is None:
        from repro.kernels.ops import on_tpu

        use_pallas = on_tpu()
    pos = cache["length"]
    params = cast_params(params, cfg)
    x = _embed(params, cfg, token)
    P = len(cfg.block_pattern)

    def group_body(x, scanned):
        group_params, group_cache = scanned
        new_cache = {}
        for k in range(P):
            kind = cfg.block_pattern[k]
            x, ne = _decode_layer(
                group_params[f"p{k}"], x, kind, cfg,
                group_cache[f"p{k}"], pos, use_pallas=use_pallas,
            )
            new_cache[f"p{k}"] = ne
        return x, new_cache

    x, new_g_cache = lax.scan(
        group_body, x, (params["groups"], cache["groups"])
    )
    new_rest = {}
    for k in range(cfg.n_layers % P):
        kind = cfg.block_pattern[k]
        x, ne = _decode_layer(
            params["rest"][f"r{k}"], x, kind, cfg,
            cache["rest"][f"r{k}"], pos, use_pallas=use_pallas,
        )
        new_rest[f"r{k}"] = ne
    logits = anchor_logits(_unembed(params, cfg, x)[:, 0])
    new_cache = dict(cache)
    new_cache.update(
        {"groups": new_g_cache, "rest": new_rest, "length": pos + 1}
    )
    return logits, new_cache


def prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    enc_frames: Optional[jnp.ndarray] = None,
    visual_embeds: Optional[jnp.ndarray] = None,
    last_only: bool = False,
) -> Tuple[jnp.ndarray, PyTree]:
    """Full-sequence forward that also materializes the decode cache.

    Note: for "local" layers the produced cache is the *full-length*
    K/V (the ring-buffer view is only used in decode_step); prefill→
    decode handoff trims to the window (:func:`prefill_to_decode_cache`).
    ``last_only`` unembeds only the final position — the serving path
    never needs the full (B, S, V) logits.
    """
    logits, cache, _ = forward(
        params, cfg, tokens, enc_frames=enc_frames,
        visual_embeds=visual_embeds, return_cache=True,
        last_only=last_only,
    )
    return logits, cache


def bulk_prefill_supported(cfg: ModelConfig) -> bool:
    """Whether the bulk prefill → decode-cache handoff covers this arch.

    The full-sequence forward only materializes attention K/V cache
    entries; recurrent states (SSD, RG-LRU) and the encoder-decoder
    cross caches exist only on the decode path, so those archs hand off
    token-by-token (the exact-handoff fallback).
    """
    return (set(cfg.block_pattern) <= {"global", "local"}
            and not cfg.is_encdec)


def prefill_to_decode_cache(
    cfg: ModelConfig,
    prefill_cache: PyTree,
    max_len: int,
    dtype: Optional[str] = None,
) -> PyTree:
    """Re-lay a bulk-prefill cache into ``decode_step``'s layout.

    Prefill K/V entries are full-length ``(…, S, Kv·Dh)``; the decode
    cache holds ``(…, C, Kv·Dh)`` ring buffers with ``C =
    min(window, max_len)`` for local layers (``max_len`` for global)
    and slot convention ``slot = pos % C`` — so the handoff keeps the
    last ``min(S, C)`` positions and scatters each to its ring slot,
    reproducing exactly the state ``S`` decode steps would have built.
    """
    if not bulk_prefill_supported(cfg):
        raise ValueError(
            f"{cfg.name}: bulk prefill handoff needs an attention-only "
            f"decoder (pattern {cfg.block_pattern}); use the exact "
            f"token-by-token handoff"
        )
    dt = jnp.dtype(dtype or cfg.dtype)
    P = len(cfg.block_pattern)
    S = None

    def convert(entry, kind):
        nonlocal S
        C = _cache_len(cfg, kind, max_len)
        k, v = entry["k"], entry["v"]
        S = k.shape[-2]
        if kind != "local" and S > C:
            raise ValueError(
                f"prompt length {S} exceeds cache size {C} — raise "
                f"max_len"
            )
        keep = min(S, C)
        pos = jnp.arange(S - keep, S)
        slots = pos % C  # distinct (a contiguous run of length ≤ C)

        def scatter(x):
            buf = jnp.zeros(x.shape[:-2] + (C, x.shape[-1]), dt)
            return buf.at[..., slots, :].set(
                x[..., S - keep :, :].astype(dt)
            )

        return {"k": scatter(k), "v": scatter(v)}

    cache = {
        "groups": {
            f"p{k}": convert(prefill_cache["groups"][f"p{k}"],
                             cfg.block_pattern[k])
            for k in range(P)
        },
        "rest": {
            f"r{k}": convert(prefill_cache["rest"][f"r{k}"],
                             cfg.block_pattern[k])
            for k in range(cfg.n_layers % P)
        },
    }
    cache["length"] = jnp.asarray(S, jnp.int32)
    return cache
