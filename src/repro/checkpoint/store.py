"""Fault-tolerant checkpointing.

Design for 1000+ nodes (DESIGN.md §5):
  * atomic: write to ``<dir>/tmp.<step>``, fsync, rename — a crashed
    save never corrupts the latest checkpoint,
  * manifest.json tracks steps + config hash; restore validates it,
  * keep-N garbage collection,
  * the data-iterator state is part of the checkpoint (exact resume),
  * pytrees are stored as flat ``.npz`` (one file per save here; on a
    real cluster each host writes its own param shard — the layout maps
    1:1 because keys are tree paths).

Elastic restarts: ``elastic.replan`` re-runs JNCSS on the surviving
topology and re-assigns data parts; model state is topology-independent
so restore works across cluster sizes.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"

#: On-disk layout version.  Bump whenever the checkpoint payload gains,
#: loses or re-shapes a field (state tree structure, ``extra`` schema) —
#: a stale checkpoint then fails with a clear message at restore time
#: instead of a cryptic pytree-structure error deep in the training
#: loop.  v2: elastic state in ``extra`` (streams, detector, deployed
#: code, EF residuals, cluster shrink record).
SCHEMA_VERSION = 2


def _flatten(tree: PyTree, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}#{i}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> PyTree:
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict):
            if node and all(k.startswith("#") for k in node):
                return [fix(node[f"#{i}"]) for i in range(len(node))]
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def config_hash(obj: Any) -> str:
    return hashlib.sha256(
        json.dumps(repr(obj), sort_keys=True).encode()
    ).hexdigest()[:16]


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3,
                 cfg_hash: str = ""):
        self.dir = directory
        self.keep = keep
        self.cfg_hash = cfg_hash
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def manifest(self) -> Dict:
        if not os.path.exists(self.manifest_path):
            return {"steps": [], "cfg_hash": self.cfg_hash}
        with open(self.manifest_path) as f:
            return json.load(f)

    def _write_manifest(self, man: Dict):
        tmp = self.manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(man, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.manifest_path)

    # ------------------------------------------------------------------
    def save(self, step: int, state: PyTree,
             extra: Optional[Dict] = None) -> str:
        """Atomic save of a full training state pytree.

        ``extra`` keys that are JSON-serializable land in meta.json;
        array-valued entries (pytrees of ndarrays — detector EWMA
        buffers, error-feedback residuals, …) are flattened into a
        sibling ``extra.npz`` and merged back on :meth:`restore`.
        """
        state = jax.tree.map(np.asarray, state)
        flat = _flatten(state)
        json_extra: Dict = {}
        arr_extra: Dict = {}
        for k, v in (extra or {}).items():
            try:
                json.dumps(v)
                json_extra[k] = v
            except TypeError:
                arr_extra[k] = jax.tree.map(np.asarray, v)
        tmp_dir = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
        os.makedirs(tmp_dir, exist_ok=True)
        path = os.path.join(tmp_dir, "state.npz")
        np.savez(path, **flat)
        if arr_extra:
            np.savez(
                os.path.join(tmp_dir, "extra.npz"), **_flatten(arr_extra)
            )
        meta = {
            "step": step,
            "time": time.time(),
            "schema_version": SCHEMA_VERSION,
            "cfg_hash": self.cfg_hash,
            "extra": json_extra,
            "n_arrays": len(flat),
            "bytes": int(sum(v.nbytes for v in flat.values())),
        }
        with open(os.path.join(tmp_dir, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp_dir, final)
        man = self.manifest()
        man["cfg_hash"] = self.cfg_hash
        man["steps"] = sorted(set(man["steps"] + [step]))
        self._write_manifest(man)
        self._gc()
        return final

    def _gc(self):
        man = self.manifest()
        steps = man["steps"]
        while len(steps) > self.keep:
            victim = steps.pop(0)
            d = os.path.join(self.dir, f"step_{victim:010d}")
            if os.path.exists(d):
                shutil.rmtree(d)
        man["steps"] = steps
        self._write_manifest(man)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = self.manifest()["steps"]
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None
                ) -> Tuple[int, PyTree, Dict]:
        """→ (step, state, extra).  Validates the config hash."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        found = meta.get("schema_version", 1)
        if found != SCHEMA_VERSION:
            raise ValueError(
                f"checkpoint {d} was written with schema v{found}, but "
                f"this build reads v{SCHEMA_VERSION} — the stored "
                f"state/extra layout is incompatible (fields were "
                f"added/removed since).  Restore it with the matching "
                f"release, or re-serialize it before resuming."
            )
        if self.cfg_hash and meta["cfg_hash"] and \
                meta["cfg_hash"] != self.cfg_hash:
            raise ValueError(
                f"checkpoint config hash {meta['cfg_hash']} != "
                f"current {self.cfg_hash}"
            )
        with np.load(os.path.join(d, "state.npz")) as z:
            flat = {k: z[k] for k in z.files}
        extra = dict(meta.get("extra", {}))
        extra_path = os.path.join(d, "extra.npz")
        if os.path.exists(extra_path):
            with np.load(extra_path) as z:
                extra.update(_unflatten({k: z[k] for k in z.files}))
        return step, _unflatten(flat), extra
