from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    make_optimizer,
    momentum,
    sgd,
)
