"""First-party optimizers (no optax on the box).

``Optimizer`` is a pair of pure functions:
    init(params)                      → state pytree
    update(grads, state, params, lr)  → (updates, new_state)
with updates applied as ``p + u``.  Gradient clipping and schedules are
composed by the train step builder.

``adafactor`` (factored second moments) is what makes the 400B MoE's
optimizer state fit 16 GB/chip HBM in the dry-run memory analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr_at(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr_at


# ----------------------------------------------------------------------
def sgd() -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, lr, weight_decay=0.0):
        def u(g, p):
            return -(lr * (g.astype(jnp.float32)
                           + weight_decay * p.astype(jnp.float32))
                     ).astype(p.dtype)

        return jax.tree.map(u, grads, params), state

    return Optimizer("sgd", init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, lr, weight_decay=0.0):
        m = jax.tree.map(
            lambda mv, g: beta * mv + g.astype(jnp.float32),
            state["m"], grads,
        )
        upd = jax.tree.map(
            lambda mv, p: -(lr * (mv + weight_decay
                                  * p.astype(jnp.float32))).astype(p.dtype),
            m, params,
        )
        return upd, {"m": m}

    return Optimizer("momentum", init, update)


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr, weight_decay=0.0):
        t = state["t"] + 1
        m = jax.tree.map(
            lambda mv, g: b1 * mv + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda vv, g: b2 * vv + (1 - b2)
            * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def u(mv, vv, p):
            step = (mv / c1) / (jnp.sqrt(vv / c2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return -(lr * step).astype(p.dtype)

        return (
            jax.tree.map(u, m, v, params),
            {"m": m, "v": v, "t": t},
        )

    return Optimizer("adamw", init, update)


def adafactor(eps: float = 1e-30, clip_thresh: float = 1.0) -> Optimizer:
    """Factored second moments (Shazeer & Stern), β1 = 0.

    Matrices (ndim ≥ 2) store one row- and one column- accumulator over
    the trailing two dims instead of a full second-moment tensor —
    O(n+m) versus O(n·m) state.
    """

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def make(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "acc": jax.tree.map(make, params,
                                is_leaf=lambda x: isinstance(x, jnp.ndarray)),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr, weight_decay=0.0):
        t = state["t"] + 1
        beta2 = 1.0 - t.astype(jnp.float32) ** -0.8

        def upd(g, acc, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if _factored(p):
                vr = beta2 * acc["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * acc["vc"] + (1 - beta2) * g2.mean(-2)
                denom = jnp.maximum(vr.mean(-1, keepdims=True), eps)
                vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                step = gf / jnp.sqrt(vhat + eps)
                new_acc = {"vr": vr, "vc": vc}
            else:
                v = beta2 * acc["v"] + (1 - beta2) * g2
                step = gf / jnp.sqrt(v + eps)
                new_acc = {"v": v}
            # update clipping (RMS ≤ clip_thresh), as in the paper
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + eps)
            step = step / jnp.maximum(1.0, rms / clip_thresh)
            step = step + weight_decay * p.astype(jnp.float32)
            return (-(lr * step)).astype(p.dtype), new_acc

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_a = treedef.flatten_up_to(state["acc"])
        outs = [upd(g, a, p) for g, a, p in zip(flat_g, flat_a, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_acc = treedef.unflatten([o[1] for o in outs])
        return updates, {"acc": new_acc, "t": t}

    return Optimizer("adafactor", init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    return {
        "sgd": sgd,
        "momentum": momentum,
        "adamw": adamw,
        "adafactor": adafactor,
    }[name](**kw)
