import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init).  Do not move them.

"""Multi-pod dry-run CLI (assignment deliverable e).

For every (architecture × input shape × mesh) cell:
    lower → compile → memory_analysis / cost_analysis / collective bytes,
on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh, using
ShapeDtypeStruct inputs only (no allocation).  The machinery lives in
:mod:`repro.api.aot` (public); this module is the CLI + the env hook
that forces the 512 host devices before jax initializes.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun
    python -m repro.launch.dryrun --all --multi-pod --out results/dryrun
"""
import argparse
import json

from repro.api.aot import HBM_BW, LINK_BW, PEAK_FLOPS, run_cell  # noqa: F401
from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--sharded-accum", action="store_true")
    ap.add_argument("--kv-repeat", action="store_true")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "save_block_outputs"])
    ap.add_argument("--mode", default="2d", choices=["2d", "dp_only"])
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel activation anchors on the "
                         "pjit path: the inter-block activations pin "
                         "the seq dim (not the feature dim) to 'model' "
                         "— GSPMD lowers the TP all-reduces as "
                         "reduce-scatter/all-gather pairs")
    ap.add_argument("--moe-ep", default="model", choices=["model", "data"])
    ap.add_argument("--microbatch", type=int, default=32)
    ap.add_argument("--out", default="")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (perf variants)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]

    results = []
    for a, s in cells:
        rec = run_cell(
            a, s, multi_pod=args.multi_pod, fsdp=not args.no_fsdp,
            microbatch=args.microbatch, remat=not args.no_remat,
            flash=args.flash, sharded_accum=args.sharded_accum,
            kv_repeat=args.kv_repeat, remat_policy=args.remat_policy,
            mode=args.mode, moe_ep_axis=args.moe_ep,
            seq_shard=args.seq_shard,
        )
        results.append(rec)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = "multi" if args.multi_pod else "single"
            if args.tag:
                tag += "__" + args.tag
            path = os.path.join(args.out, f"{a}__{s}__{tag}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} failed "
          f"of {len(results)} cells")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
