"""Production mesh factory (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
