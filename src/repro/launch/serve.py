"""Batched serving driver CLI: prefill + decode with KV caches.

Front-end over :meth:`repro.api.CodedSession.generate`: the session
owns the compiled prefill/decode steps — the prompt is prefetched
through the bulk ``tf.prefill`` lowering (one dispatch, handed off into
the decode ring buffers) instead of the old S-step ``decode_step``
loop, and ``--tp N`` shards both steps tensor-parallel across N host
devices from the same pspec rules training uses.

``--exact-handoff`` keeps the token-by-token prefill as a debug path
(it is also the automatic fallback for recurrent / encoder-decoder
archs whose states only exist on the decode path).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --batch 4 --prompt-len 16 --gen 32
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --batch 4 --gen 32 --tp 2
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.api import CodedSession
from repro.api.serving import generate, prefill_into_cache  # noqa: F401
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as tf


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard the prefill/"
                         "decode steps over a 'model' mesh axis of N "
                         "host devices (1 = single host)")
    ap.add_argument("--exact-handoff", action="store_true",
                    help="debug: feed the prompt through decode_step "
                         "token by token instead of the bulk prefill")
    ap.add_argument("--f32", action="store_true",
                    help="force float32 compute: bf16 rounding depends "
                         "on the shard layout, f32 makes greedy tokens "
                         "invariant to the TP degree")
    ap.add_argument("--tokens-out", default="",
                    help="write the generated token matrix as JSON")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.f32:
        import dataclasses

        cfg = dataclasses.replace(cfg, dtype="float32")
    session = CodedSession(None, cfg, tp=args.tp, seed=args.seed)
    rng = jax.random.PRNGKey(args.seed)
    prompt = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab
    )
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(
            rng, (args.batch, cfg.enc_len, cfg.d_model)
        )
    t0 = time.time()
    toks = session.generate(
        prompt, args.gen,
        max_len=args.prompt_len + args.gen + 1, enc_frames=enc,
        seed=args.seed, exact_handoff=args.exact_handoff,
    )
    dt = time.time() - t0
    mode = "exact-handoff" if (args.exact_handoff
                               or not tf.bulk_prefill_supported(cfg)) \
        else "bulk-prefill"
    print(f"[serve] {args.arch} (tp={args.tp}, {mode}): generated "
          f"{toks.shape} tokens in {dt:.1f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("[serve] sample:", toks[0][:16].tolist())
    if args.tokens_out:
        with open(args.tokens_out, "w") as f:
            json.dump({"tp": args.tp, "tokens": toks.tolist()}, f)
    return toks


if __name__ == "__main__":
    main()
