"""Batched serving driver: prefill + decode with KV caches.

Single-host reference of the serving path that decode_32k/long_500k
dry-run at scale.  Demonstrates prefill→decode handoff (including the
local-attention ring-buffer trim) and batched token generation.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as tf


def prefill_into_cache(params, cfg, tokens, max_len, enc_frames=None):
    """Run prefill and materialize a decode cache of size max_len."""
    B, S = tokens.shape
    cache = tf.init_cache(cfg, B, max_len, dtype="float32")
    if cfg.is_encdec:
        cache = tf.fill_cross_cache(params, cfg, enc_frames, cache)
    # feed tokens through decode_step (simplest exact handoff — the
    # dryrun prefill path instead lowers tf.prefill for the bulk form)
    step = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c))
    logits = None
    for t in range(S):
        logits, cache = step(params, tokens[:, t : t + 1], cache)
    return logits, cache


def generate(params, cfg, prompt, gen_len, max_len, enc_frames=None,
             greedy=True, seed=0):
    logits, cache = prefill_into_cache(
        params, cfg, prompt, max_len, enc_frames
    )
    step = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c))
    rng = jax.random.PRNGKey(seed)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(gen_len):
        out.append(np.asarray(tok))
        logits, cache = step(params, tok, cache)
        if greedy:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits)[:, None].astype(
                jnp.int32)
    return np.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = tf.init_params(rng, cfg)
    prompt = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab
    )
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(
            rng, (args.batch, cfg.enc_len, cfg.d_model)
        )
    t0 = time.time()
    toks = generate(
        params, cfg, prompt, args.gen,
        max_len=args.prompt_len + args.gen + 1, enc_frames=enc,
    )
    dt = time.time() - t0
    print(f"[serve] {args.arch}: generated {toks.shape} tokens in "
          f"{dt:.1f}s ({args.batch*args.gen/dt:.1f} tok/s)")
    print("[serve] sample:", toks[0][:16].tolist())
    return toks


if __name__ == "__main__":
    main()
