"""train_step / serve_step builders shared by dryrun.py, train.py, serve.py.

The train step includes: microbatched gradient accumulation (lax.scan),
global-norm clipping, cosine LR schedule, the optimizer update, and the
HGC hook — per-example coded weights arrive in ``batch["weights"]`` and
a per-shard-group decode weight ``batch["lam"]`` scales the loss, so the
pjit gradient all-reduce computes the *decoded* coded aggregate
(DESIGN.md §3, integration point 1).
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer as tf
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer

PyTree = Any

# per-arch optimizer defaults for the production configs: adafactor where
# Adam moments would not fit 16 GB/chip HBM (the 400B MoE).
ARCH_OPTIMIZER = {
    "llama4-maverick-400b-a17b": "adafactor",
    "gemma3-27b": "adafactor",
}


def default_optimizer_name(cfg: ModelConfig, tcfg: TrainConfig) -> str:
    return ARCH_OPTIMIZER.get(cfg.name, tcfg.optimizer)


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    optimizer=None,
    accum_shardings=None,
) -> Callable:
    """Returns train_step(params, opt_state, batch, step) →
    (params, opt_state, metrics).

    ``accum_shardings``: optional params-shaped NamedSharding tree —
    pins the f32 gradient accumulator to the FSDP param shards so each
    microbatch's gradient reduction lowers as a reduce-scatter instead
    of a full all-reduce (§Perf hillclimb knob).
    """
    if optimizer is None:
        optimizer = make_optimizer(default_optimizer_name(cfg, tcfg))
    lr_at = cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)

    def loss_fn(params, batch):
        # HGC hook: batch["weights"] carries coding coefficient × λ_ij
        # per example; the pjit gradient reduction then yields the
        # decoded coded aggregate Σ λ_ij G_ij = g exactly.
        return tf.loss_and_metrics(params, cfg, batch)

    def grads_of(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 0:
            B = batch["tokens"].shape[0]
            mb = min(tcfg.microbatch, B)
            n_micro = max(B // mb, 1)

            # reshape (B, …) → (n_micro, mb, …) and scan over the leading
            # axis: scan's xs slicing keeps the batch-dim sharding intact
            # (a dynamic_slice over a sharded batch dim would force XLA
            # to gather across shards).
            def split(k, x):
                if k == "positions" and x.ndim == 3 and x.shape[1] == B:
                    # M-RoPE positions: (3, B, S) — batch is axis 1
                    r = x.reshape(3, n_micro, mb, x.shape[2])
                    return jnp.moveaxis(r, 1, 0)  # (n_micro, 3, mb, S)
                if x.ndim == 0 or x.shape[0] != B:
                    return None
                return x.reshape(n_micro, mb, *x.shape[1:])

            xs = {k: split(k, v) for k, v in batch.items()}
            consts = {k: v for k, v in batch.items() if xs.get(k) is None}
            xs = {k: v for k, v in xs.items() if v is not None}

            def body(carry, micro_xs):
                acc, msum = carry
                micro = dict(consts)
                micro.update(micro_xs)
                (_, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, micro)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(a.dtype), acc, g
                )
                return (acc, msum + metrics["loss"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            if accum_shardings is not None:
                zeros = jax.tree.map(
                    lambda z, s: jax.lax.with_sharding_constraint(z, s),
                    zeros, accum_shardings,
                )
            (gsum, lsum), _ = lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), xs
            )
            if "denom" in batch:
                # fixed-denominator (linear/coded) loss: microbatch
                # losses SUM to the full-batch loss — no /n_micro
                grads, metrics = gsum, {"loss": lsum}
            else:
                grads = jax.tree.map(lambda g: g / n_micro, gsum)
                metrics = {"loss": lsum / n_micro}
            return grads, metrics
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return grads, {"loss": metrics["loss"]}

    def train_step(params, opt_state, batch, step):
        grads, metrics = grads_of(params, batch)
        if tcfg.grad_clip > 0:
            grads = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_at(step)
        updates, new_state = optimizer.update(
            grads, opt_state, params, lr, tcfg.weight_decay
        )
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = dict(metrics)
        metrics["lr"] = lr
        metrics["grad_norm"] = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        return new_params, new_state, metrics

    train_step.optimizer = optimizer
    return train_step


_WARNED: set = set()


def _warn_once(old: str, new: str) -> None:
    if old in _WARNED:
        return
    _WARNED.add(old)
    warnings.warn(
        f"{old} is deprecated; use {new} instead",
        DeprecationWarning, stacklevel=3,
    )


def make_dist_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh,
    optimizer=None,
    axes: Tuple[str, str] = ("pod", "data"),
) -> Callable:
    """Deprecated direct entry point — :class:`repro.api.CodedSession`
    owns the dist step (mesh, shardings, λ, EF residuals) end to end."""
    _warn_once("steps_lib.make_dist_train_step",
               "repro.api.CodedSession (it compiles and owns the dist "
               "train step)")
    return _make_dist_train_step(cfg, tcfg, mesh, optimizer=optimizer,
                                 axes=axes)


def _make_dist_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh,
    optimizer=None,
    axes: Tuple[str, str] = ("pod", "data"),
) -> Callable:
    """Mesh-aware train step: the coded decode runs as real collectives.

    Returns ``train_step(params, opt_state, batch, lam, residual, step)
    → (params, opt_state, residual, metrics)``.  Each (pod, data) shard
    group receives its own slice of the batch — the examples of worker
    (i, j)'s assigned parts, weighted by the coding coefficients only —
    and computes the gradient of its local weighted loss, which IS its
    encoded message G_ij (eq. 22).  The decode then runs as the
    two-stage λ-weighted psum of :mod:`repro.dist.grad_sync` (eqs.
    25/27); with ``tcfg.grad_compression`` set (int8 | int4 | fp8) the
    cross-pod hop rides the blockwise-quantized + error-feedback path
    of that codec and ``residual``
    threads the per-pod EF state (leaves ``(n_pods, *param_shape)``,
    sharded over "pod" and, under TP, over "model" like the gradient
    leaf it telescopes against; pass an empty dict otherwise).

    A "model" mesh axis of size tp > 1 runs REAL tensor parallelism
    inside the shard_map region: params enter model-sharded per the
    pspec rules of :mod:`repro.dist.sharding` (the same single source
    of truth the pjit path partitions from), the forward runs
    Megatron-style (column-parallel in-projections, row-parallel
    out-projections psum'd over "model", vocab-parallel logits decoded
    by the cross-entropy's single fused psum), and the per-group loss
    comes out replicated across model shards — the loss metric psums
    over "model" exactly once (inside the CE), then only over
    (data, pod).  Because each shard's backward of the replicated
    objective computes ``∂(Σ_shards φ)/∂(local copy)``, gradients are
    corrected before the coded decode: model-sharded leaves divide by
    tp, replicated leaves psum over "model" and divide by tp.

    MoE archs: the λ-weighted decode is exact for the coeff-weighted
    DATA loss only, so λ is folded into the local objective and the
    load-balancing aux gradient is decoded with *uniform* weights
    ``1/(n·m)`` (stragglers included — the aux regularizer must not
    depend on the straggler pattern); the two-stage psum then runs
    unweighted.

    ``tcfg.seq_shard_activations`` turns on sequence parallelism
    through the same ShardCtx seam: between a row-parallel
    reduce-scatter and the next column-parallel all_gather the
    activations (and the remat-saved block outputs) hold only the
    local 1/tp seq block — identical collective bytes, tp× less
    activation state.  The gradient correction then applies against
    :func:`sharding.seq_sharded_mask` (the replicated-leaf psum is
    load-bearing there: per-shard grads are seq-block partials).

    A leading "stage" mesh axis of size pp > 1 additionally runs
    PIPELINE parallelism inside the same shard_map region: the stacked
    layer groups enter stage-sharded on their leading dim (stage s
    holds groups ``[s·G/pp, (s+1)·G/pp)`` — :func:`sharding.
    stage_layer_ranges`), the per-group coded batch splits into
    ``tcfg.microbatches`` microbatches, and a ``lax.scan`` over the
    static schedule table (T = microbatches + pp − 1 ticks; stage s
    works on microbatch t − s at tick t) drives the forward pipeline
    with ``ppermute`` activation handoffs — reverse-mode AD transposes
    the scan + ppermute into the mirrored backward pipeline, so the
    gradient handoffs are the same schedule reversed (GPipe-style
    fill/drain: bubble fraction (pp − 1)/T).  Off-schedule (stage,
    tick) cells compute on garbage-over-zeros that a zero mask keeps
    out of the loss — and, transposed, out of every gradient.  The
    embedding runs on every stage (only stage 0's result enters the
    pipeline); the remainder layers + unembed + CE ride the last
    stage; the whisper encoder runs stage-replicated on the full local
    batch.  Per-stage gradient buckets then flow through the SAME λ
    decode: ``stage_correct`` mirrors ``tp_correct`` over "stage"
    (stage-sharded leaves /pp, stage-replicated leaves psum over
    "stage" — load-bearing: each stage's grads of the embedding/head/
    encoder cover only its own paths — then /pp) before the coded
    psum, and the int8 EF residuals slice stage-wise exactly like the
    gradient leaf they telescope against.

    λ arrives as a runtime (pods, data) operand, so straggler drops and
    elastic replans at fixed (tolerance, K) never recompile — TP, SP
    and PP add only static shape specialization, never λ-dependent
    shapes.  The microbatched accumulation of :func:`make_train_step`
    is not replicated here: the per-group batch is already 1/(n·m) of
    the global batch (the PP microbatches split it further for the
    pipeline, they do not accumulate extra examples).
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist import grad_sync
    from repro.dist import sharding as shard_lib
    from repro.dist._compat import shard_map

    if optimizer is None:
        optimizer = make_optimizer(default_optimizer_name(cfg, tcfg))
    lr_at = cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
    pod_axis, data_axis = axes
    n_pods = mesh.shape[pod_axis]
    n_groups = n_pods * mesh.shape[data_axis]
    compressed = tcfg.grad_compression != "none"
    if compressed:
        from repro.dist import compression as _comp

        if tcfg.grad_compression not in _comp.COMPRESSION_MODES:
            raise ValueError(
                f"grad_compression={tcfg.grad_compression!r} not in "
                f"{('none',) + _comp.COMPRESSION_MODES}"
            )

    ctx = shard_lib.make_shard_ctx(
        mesh, seq_shard=tcfg.seq_shard_activations
    )
    tp = ctx.tp
    if tp > 1:
        shard_lib.validate_tp(cfg, tp)
    pp = ctx.pp
    pp_microbatches = 1
    if pp > 1:
        shard_lib.validate_pp(cfg, pp,
                              microbatches=tcfg.microbatches)
        pp_microbatches = tcfg.microbatches or pp
    # single source of truth: the pjit path's pspec rules, projected
    # onto the model axis for the shard_map region (params enter
    # model-sharded — no replicated entry, no re-shard on exit)
    params_abs, _ = abstract_state(cfg, tcfg, optimizer)
    pspecs = shard_lib.fit_pspecs(
        shard_lib.params_pspecs(params_abs, cfg, mesh, fsdp=tcfg.fsdp,
                                head_aligned=True),
        params_abs, mesh,
    )
    param_specs = shard_lib.model_axis_only(pspecs)
    # SP makes per-shard grads of replicated leaves seq-block partials;
    # the mask tells tp_correct which leaves need the completing psum
    tp_mask = (shard_lib.seq_sharded_mask(pspecs) if ctx.sp
               else shard_lib.model_sharded_mask(pspecs))
    res_spec_tree = jax.tree.map(
        lambda s: P(pod_axis, *tuple(s)), param_specs,
        is_leaf=lambda x: isinstance(x, P),
    )

    def _pipeline_terms(params, batch):
        """Microbatched stage pipeline over this group's coded batch.

        Returns ``(loss_local, aux_tot)``, both replicated across
        stages (via the closing stage psum).  ``loss_local`` matches
        the non-pipelined ``loss_and_metrics`` loss exactly in fp32:
        the per-microbatch nll/weight sums are additive and the
        denominator is shared.  ``aux_tot`` is the per-microbatch MEAN
        of the MoE aux (exactly the full-batch aux at microbatches=1;
        for M > 1 the router capacity and the mean-based balance terms
        see microbatch-sized token counts, the standard pipeline
        semantic).
        """
        M = pp_microbatches
        tokens = batch["tokens"]
        Bl, S = tokens.shape
        if Bl % M:
            raise ValueError(
                f"{cfg.name}: pipeline parallelism needs the per-group "
                f"batch ({Bl} rows) divisible by microbatches={M}"
            )
        mb = Bl // M
        paramsC = tf.cast_params(params, cfg)
        stage = lax.axis_index(shard_lib.STAGE_AXIS)

        def mb_split(k, v):
            if k == "positions" and v.ndim == 3 and v.shape[1] == Bl:
                # M-RoPE positions (3, Bl, S): batch is axis 1
                r = v.reshape(3, M, mb, v.shape[2])
                return jnp.moveaxis(r, 1, 0)  # (M, 3, mb, S)
            if getattr(v, "ndim", 0) == 0 or v.shape[0] != Bl:
                return None
            return v.reshape(M, mb, *v.shape[1:])

        split = {k: mb_split(k, v) for k, v in batch.items()
                 if k != "enc_frames"}
        split = {k: v for k, v in split.items() if v is not None}
        enc_split = enc_pos = None
        if cfg.is_encdec:
            # the encoder runs ONCE, stage-replicated, on the full
            # local batch; each stage's encoder grads cover only its
            # own groups' cross-attention uses and the stage psum of
            # stage_correct completes the layer-wise sum
            enc_out, enc_pos = tf.encode_frames(
                paramsC, cfg, batch["enc_frames"], ctx
            )
            enc_split = enc_out.reshape(M, mb, *enc_out.shape[1:])

        S_loc = S // tp if ctx.sp else S
        T = M + pp - 1
        perm = [(s, s + 1) for s in range(pp - 1)]

        def tick(carry, t):
            x_recv, nll_acc, w_acc, aux_acc = carry
            # stage s works on microbatch t − s; the clip keeps the
            # dynamic slice in-bounds on off-schedule ticks (their
            # output is masked away below)
            cur = jnp.clip(t - stage, 0, M - 1)
            micro = {
                k: lax.dynamic_index_in_dim(v, cur, 0, keepdims=False)
                for k, v in split.items()
            }
            x0, pos = tf.embed_tokens(
                paramsC, cfg, micro["tokens"],
                positions=micro.get("positions"),
                visual_embeds=micro.get("visual_embeds"), ctx=ctx,
            )
            enc_sl = None
            if enc_split is not None:
                enc_sl = lax.dynamic_index_in_dim(
                    enc_split, cur, 0, keepdims=False
                )
            # SPMD uniformity: every stage embeds every tick, but only
            # stage 0's embedding enters the pipeline — elsewhere the
            # ppermute'd carry does (AD routes cotangents accordingly)
            x_in = jnp.where(stage == 0, x0, x_recv)
            x_out, _, aux_g = tf._apply_groups(
                paramsC["groups"], cfg, x_in, pos, enc_sl, enc_pos,
                ctx=ctx,
            )
            nll_sum, w_sum, aux_r = tf.head_loss_terms(
                paramsC, cfg, x_out, micro["targets"],
                micro.get("weights"), pos, enc_sl, enc_pos, ctx=ctx,
            )
            # the static schedule table: cell (stage, tick) is live iff
            # stage ≤ t < stage + M.  Off-schedule cells compute on
            # garbage-over-zeros; the zero mask keeps that out of the
            # loss and (transposed) out of every gradient.
            valid = ((t >= stage) & (t < stage + M)).astype(jnp.float32)
            lastf = jnp.where(stage == pp - 1, valid, 0.0)
            nll_acc = nll_acc + lastf * nll_sum
            w_acc = w_acc + lastf * w_sum
            # per-microbatch-mean aux (== full-batch aux at M == 1)
            aux_acc = aux_acc + (valid * aux_g + lastf * aux_r) / M
            x_send = lax.ppermute(x_out, shard_lib.STAGE_AXIS, perm)
            return (x_send, nll_acc, w_acc, aux_acc), None

        zero = jnp.zeros((), jnp.float32)
        carry0 = (
            jnp.zeros((mb, S_loc, cfg.d_model), jnp.dtype(cfg.dtype)),
            zero, zero, zero,
        )
        (_, nll_acc, w_acc, aux_acc), _ = lax.scan(
            tick, carry0, jnp.arange(T)
        )
        # only the last stage accumulated loss terms; the stage psum
        # both collects them and re-replicates (out_specs leave "stage"
        # unmentioned, which demands replication over it)
        nll_tot = lax.psum(nll_acc, shard_lib.STAGE_AXIS)
        w_tot = lax.psum(w_acc, shard_lib.STAGE_AXIS)
        aux_tot = lax.psum(aux_acc, shard_lib.STAGE_AXIS)
        denom = batch.get("denom")
        if denom is None:
            denom = jnp.maximum(w_tot, 1.0)
        return nll_tot / denom, aux_tot

    def loss_metrics(params, batch):
        """(total, metrics) — the one seam both objectives share."""
        if pp > 1:
            loss_local, aux_tot = _pipeline_terms(params, batch)
            total = loss_local + tf.AUX_WEIGHT * aux_tot
            return total, {"loss": loss_local, "aux_loss": aux_tot}
        return tf.loss_and_metrics(params, cfg, batch, ctx=ctx)

    def loss_fn(params, batch):
        return loss_metrics(params, batch)

    def moe_obj(params, batch, lam_s):
        # λ folded into the data term; aux decoded with uniform weights
        # (a SEPARATE uniform psum in effect: the unweighted two-stage
        # psum below sums λ·∇data + (aw/nm)·∇aux exactly)
        total, m = loss_metrics(params, batch)
        obj = (lam_s.astype(jnp.float32) * m["loss"]
               + (tf.AUX_WEIGHT / n_groups) * m["aux_loss"])
        return obj, m

    def tp_correct(g):
        """Per-shard grads of the model-replicated objective → exact.

        Inside shard_map each shard's backward yields
        ``∂(Σ_shards φ_j)/∂(its copy)``: sharded leaves carry a uniform
        tp factor; replicated leaves additionally hold only their own
        shard's partial paths, so they psum over "model" first.
        """
        if tp == 1:
            return g

        def one(gl, sharded):
            if not sharded:
                gl = lax.psum(gl, shard_lib.MODEL_AXIS)
            return gl / tp

        return jax.tree.map(one, g, tp_mask)

    stage_mask = shard_lib.stage_sharded_mask(pspecs)

    def stage_correct(g):
        """The "stage" twin of :func:`tp_correct`.

        The pipelined objective is replicated across stages (closing
        stage psum), so each stage's backward yields
        ``∂(Σ_stages φ_s)/∂(its copy)``: stage-sharded leaves (the
        layer-group stacks) carry a uniform pp factor; stage-replicated
        leaves (embedding/head/rest/encoder) additionally hold only
        their own stage's paths — stage 0's table grad is the embed
        contribution, the last stage's the unembed one, the encoder's
        per-stage cross-attention uses — so they psum over "stage"
        first (load-bearing, not just a de-duplication).
        """
        if pp == 1:
            return g

        def one(gl, sharded):
            if not sharded:
                gl = lax.psum(gl, shard_lib.STAGE_AXIS)
            return gl / pp

        return jax.tree.map(one, g, stage_mask)

    def local_grads(params, batch, lam, residual):
        lam_s = lam.reshape(())
        if cfg.is_moe:
            (_, m), g = jax.value_and_grad(moe_obj, has_aux=True)(
                params, batch, lam_s
            )
            psum_lam = jnp.ones((), jnp.float32)
        else:
            (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            psum_lam = lam_s
        g = stage_correct(tp_correct(g))
        # decoded loss Σ_ij λ_ij L_ij — matches the single-host weighted
        # loss (weights there carry coeff × λ over the full batch).
        # Under TP the per-group loss is already psum'd over "model"
        # exactly once (inside the CE) ⇒ replicated across model shards;
        # reducing over (data, pod) only avoids double-counting it.
        loss = lax.psum(
            lax.psum(m["loss"] * lam_s.astype(jnp.float32), data_axis),
            pod_axis,
        )
        if compressed:
            g, residual = grad_sync.compressed_coded_psum(
                g, psum_lam, residual, n_pods=n_pods, axes=axes,
                block=tcfg.grad_compression_block,
                mode=tcfg.grad_compression,
            )
        else:
            g = grad_sync.coded_weighted_psum(g, psum_lam, axes)
        if cfg.is_moe:
            aux = lax.psum(
                lax.psum(m["aux_loss"] / n_groups, data_axis), pod_axis
            )
            return g, residual, loss, aux
        return g, residual, loss

    def batch_spec(key, v):
        if getattr(v, "ndim", 0) == 0:
            return P()  # denom: the fixed global normalizer, replicated
        if key == "positions":  # M-RoPE (3, B, S): batch is axis 1
            return P(None, (pod_axis, data_axis), *([None] * (v.ndim - 2)))
        return P((pod_axis, data_axis), *([None] * (v.ndim - 1)))

    def train_step(params, opt_state, batch, lam, residual, step):
        batch_specs = {k: batch_spec(k, v) for k, v in batch.items()}
        res_specs = res_spec_tree if residual else type(residual)()
        out_extra = (P(),) if cfg.is_moe else ()
        fn = shard_map(
            local_grads,
            mesh=mesh,
            in_specs=(param_specs, batch_specs,
                      P(pod_axis, data_axis), res_specs),
            out_specs=(param_specs, res_specs, P()) + out_extra,
            check_rep=False,
        )
        out = fn(params, batch, lam, residual)
        grads, new_residual, loss = out[0], out[1], out[2]
        if tcfg.grad_clip > 0:
            grads = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_at(step)
        updates, new_state = optimizer.update(
            grads, opt_state, params, lr, tcfg.weight_decay
        )
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = {
            "loss": loss,
            "lr": lr,
            "grad_norm": jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads))
            ),
        }
        if cfg.is_moe:
            metrics["aux_loss"] = out[3]
        return new_params, new_state, new_residual, metrics

    train_step.optimizer = optimizer
    return train_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, cache, token) → (logits, new_cache)."""

    def serve_step(params, cache, token):
        return tf.decode_step(params, cfg, token, cache)

    return serve_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """prefill_step(params, batch) → (last logits, cache)."""

    def prefill_step(params, batch):
        logits, cache, _ = tf.forward(
            params, cfg, batch["tokens"],
            positions=batch.get("positions"),
            enc_frames=batch.get("enc_frames"),
            return_cache=True,
            last_only=True,
        )
        return logits[:, -1], cache

    return prefill_step


# ----------------------------------------------------------------------
# abstract inputs — the assignment's input_specs()
# ----------------------------------------------------------------------
def input_specs(
    cfg: ModelConfig, shape: ShapeConfig
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Weak-type-correct, shardable, no device allocation.  Frontend stubs
    (whisper frames / VLM patch embeds, per the assignment) appear as
    precomputed embedding tensors.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
        }
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
            specs["weights"] = jax.ShapeDtypeStruct((B, S), f32)
        if cfg.mrope_sections:
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        if cfg.is_encdec:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_len, cfg.d_model), f32
            )
        return specs
    # decode: one new token against a seq_len cache
    return {"token": jax.ShapeDtypeStruct((B, 1), i32)}


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig, optimizer=None):
    """Abstract (params, opt_state) without allocation."""
    if optimizer is None:
        optimizer = make_optimizer(default_optimizer_name(cfg, tcfg))
    params = jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg)
    )
    opt_state = jax.eval_shape(optimizer.init, params)
    return params, opt_state


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
