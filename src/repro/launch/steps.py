"""train_step / serve_step builders shared by dryrun.py, train.py, serve.py.

The train step includes: microbatched gradient accumulation (lax.scan),
global-norm clipping, cosine LR schedule, the optimizer update, and the
HGC hook — per-example coded weights arrive in ``batch["weights"]`` and
a per-shard-group decode weight ``batch["lam"]`` scales the loss, so the
pjit gradient all-reduce computes the *decoded* coded aggregate
(DESIGN.md §3, integration point 1).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer as tf
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer

PyTree = Any

# per-arch optimizer defaults for the production configs: adafactor where
# Adam moments would not fit 16 GB/chip HBM (the 400B MoE).
ARCH_OPTIMIZER = {
    "llama4-maverick-400b-a17b": "adafactor",
    "gemma3-27b": "adafactor",
}


def default_optimizer_name(cfg: ModelConfig, tcfg: TrainConfig) -> str:
    return ARCH_OPTIMIZER.get(cfg.name, tcfg.optimizer)


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    optimizer=None,
    accum_shardings=None,
) -> Callable:
    """Returns train_step(params, opt_state, batch, step) →
    (params, opt_state, metrics).

    ``accum_shardings``: optional params-shaped NamedSharding tree —
    pins the f32 gradient accumulator to the FSDP param shards so each
    microbatch's gradient reduction lowers as a reduce-scatter instead
    of a full all-reduce (§Perf hillclimb knob).
    """
    if optimizer is None:
        optimizer = make_optimizer(default_optimizer_name(cfg, tcfg))
    lr_at = cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)

    def loss_fn(params, batch):
        # HGC hook: batch["weights"] carries coding coefficient × λ_ij
        # per example; the pjit gradient reduction then yields the
        # decoded coded aggregate Σ λ_ij G_ij = g exactly.
        return tf.loss_and_metrics(params, cfg, batch)

    def grads_of(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 0:
            B = batch["tokens"].shape[0]
            mb = min(tcfg.microbatch, B)
            n_micro = max(B // mb, 1)

            # reshape (B, …) → (n_micro, mb, …) and scan over the leading
            # axis: scan's xs slicing keeps the batch-dim sharding intact
            # (a dynamic_slice over a sharded batch dim would force XLA
            # to gather across shards).
            def split(k, x):
                if k == "positions" and x.ndim == 3 and x.shape[1] == B:
                    # M-RoPE positions: (3, B, S) — batch is axis 1
                    r = x.reshape(3, n_micro, mb, x.shape[2])
                    return jnp.moveaxis(r, 1, 0)  # (n_micro, 3, mb, S)
                if x.ndim == 0 or x.shape[0] != B:
                    return None
                return x.reshape(n_micro, mb, *x.shape[1:])

            xs = {k: split(k, v) for k, v in batch.items()}
            consts = {k: v for k, v in batch.items() if xs.get(k) is None}
            xs = {k: v for k, v in xs.items() if v is not None}

            def body(carry, micro_xs):
                acc, msum = carry
                micro = dict(consts)
                micro.update(micro_xs)
                (_, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, micro)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(a.dtype), acc, g
                )
                return (acc, msum + metrics["loss"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            if accum_shardings is not None:
                zeros = jax.tree.map(
                    lambda z, s: jax.lax.with_sharding_constraint(z, s),
                    zeros, accum_shardings,
                )
            (gsum, lsum), _ = lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), xs
            )
            if "denom" in batch:
                # fixed-denominator (linear/coded) loss: microbatch
                # losses SUM to the full-batch loss — no /n_micro
                grads, metrics = gsum, {"loss": lsum}
            else:
                grads = jax.tree.map(lambda g: g / n_micro, gsum)
                metrics = {"loss": lsum / n_micro}
            return grads, metrics
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return grads, {"loss": metrics["loss"]}

    def train_step(params, opt_state, batch, step):
        grads, metrics = grads_of(params, batch)
        if tcfg.grad_clip > 0:
            grads = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_at(step)
        updates, new_state = optimizer.update(
            grads, opt_state, params, lr, tcfg.weight_decay
        )
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = dict(metrics)
        metrics["lr"] = lr
        metrics["grad_norm"] = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        return new_params, new_state, metrics

    train_step.optimizer = optimizer
    return train_step


def make_dist_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh,
    optimizer=None,
    axes: Tuple[str, str] = ("pod", "data"),
) -> Callable:
    """Mesh-aware train step: the coded decode runs as real collectives.

    Returns ``train_step(params, opt_state, batch, lam, residual, step)
    → (params, opt_state, residual, metrics)``.  Each (pod, data) shard
    group receives its own slice of the batch — the examples of worker
    (i, j)'s assigned parts, weighted by the coding coefficients only —
    and computes the gradient of its local weighted loss, which IS its
    encoded message G_ij (eq. 22).  The decode then runs as the
    two-stage λ-weighted psum of :mod:`repro.dist.grad_sync` (eqs.
    25/27); with ``tcfg.grad_compression == "int8"`` the cross-pod hop
    rides the blockwise-int8 + error-feedback path and ``residual``
    threads the per-pod EF state (leaves ``(n_pods, *param_shape)``,
    sharded over "pod"; pass an empty dict otherwise).

    λ arrives as a runtime (pods, data) operand, so straggler drops and
    elastic replans at fixed (tolerance, K) never recompile.  The
    microbatched accumulation of :func:`make_train_step` is not
    replicated here: the per-group batch is already 1/(n·m) of the
    global batch.  A "model" mesh axis is tolerated but NOT
    tensor-parallelized: params enter the shard_map region replicated
    and every model shard recomputes the same local gradient (TP
    execution lives on the pjit/dryrun path; here the axis only shards
    params/opt-state storage between steps).
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist import grad_sync
    from repro.dist._compat import shard_map

    if cfg.is_moe:
        # the λ-weighted decode is exact for the coeff-weighted DATA
        # loss only; the MoE load-balancing aux gradient would come out
        # Σ λ_ij·∇aux_ij instead of ∇aux(full batch) — a silently
        # different (straggler-dependent) regularizer than --dist off.
        raise NotImplementedError(
            f"{cfg.name}: coded decode of the MoE aux loss is not "
            "implemented — run MoE archs with --dist off"
        )
    if optimizer is None:
        optimizer = make_optimizer(default_optimizer_name(cfg, tcfg))
    lr_at = cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
    pod_axis, data_axis = axes
    n_pods = mesh.shape[pod_axis]
    compressed = tcfg.grad_compression == "int8"

    def loss_fn(params, batch):
        return tf.loss_and_metrics(params, cfg, batch)

    def local_grads(params, batch, lam, residual):
        (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        lam_s = lam.reshape(())
        # decoded loss Σ_ij λ_ij L_ij — matches the single-host weighted
        # loss (weights there carry coeff × λ over the full batch)
        loss = lax.psum(
            lax.psum(m["loss"] * lam_s.astype(jnp.float32), data_axis),
            pod_axis,
        )
        if compressed:
            g, residual = grad_sync.compressed_coded_psum(
                g, lam_s, residual, n_pods=n_pods, axes=axes,
                block=tcfg.grad_compression_block,
            )
        else:
            g = grad_sync.coded_weighted_psum(g, lam_s, axes)
        return g, residual, loss

    def batch_spec(key, v):
        if getattr(v, "ndim", 0) == 0:
            return P()  # denom: the fixed global normalizer, replicated
        if key == "positions":  # M-RoPE (3, B, S): batch is axis 1
            return P(None, (pod_axis, data_axis), *([None] * (v.ndim - 2)))
        return P((pod_axis, data_axis), *([None] * (v.ndim - 1)))

    def train_step(params, opt_state, batch, lam, residual, step):
        batch_specs = {k: batch_spec(k, v) for k, v in batch.items()}
        res_specs = jax.tree.map(
            lambda r: P(pod_axis, *([None] * (r.ndim - 1))), residual
        )
        fn = shard_map(
            local_grads,
            mesh=mesh,
            in_specs=(P(), batch_specs, P(pod_axis, data_axis), res_specs),
            out_specs=(P(), res_specs, P()),
            check_rep=False,
        )
        grads, new_residual, loss = fn(params, batch, lam, residual)
        if tcfg.grad_clip > 0:
            grads = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_at(step)
        updates, new_state = optimizer.update(
            grads, opt_state, params, lr, tcfg.weight_decay
        )
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = {
            "loss": loss,
            "lr": lr,
            "grad_norm": jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads))
            ),
        }
        return new_params, new_state, new_residual, metrics

    train_step.optimizer = optimizer
    return train_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, cache, token) → (logits, new_cache)."""

    def serve_step(params, cache, token):
        return tf.decode_step(params, cfg, token, cache)

    return serve_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """prefill_step(params, batch) → (last logits, cache)."""

    def prefill_step(params, batch):
        logits, cache, _ = tf.forward(
            params, cfg, batch["tokens"],
            positions=batch.get("positions"),
            enc_frames=batch.get("enc_frames"),
            return_cache=True,
            last_only=True,
        )
        return logits[:, -1], cache

    return prefill_step


# ----------------------------------------------------------------------
# abstract inputs — the assignment's input_specs()
# ----------------------------------------------------------------------
def input_specs(
    cfg: ModelConfig, shape: ShapeConfig
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Weak-type-correct, shardable, no device allocation.  Frontend stubs
    (whisper frames / VLM patch embeds, per the assignment) appear as
    precomputed embedding tensors.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
        }
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
            specs["weights"] = jax.ShapeDtypeStruct((B, S), f32)
        if cfg.mrope_sections:
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        if cfg.is_encdec:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_len, cfg.d_model), f32
            )
        return specs
    # decode: one new token against a seq_len cache
    return {"token": jax.ShapeDtypeStruct((B, 1), i32)}


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig, optimizer=None):
    """Abstract (params, opt_state) without allocation."""
    if optimizer is None:
        optimizer = make_optimizer(default_optimizer_name(cfg, tcfg))
    params = jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg)
    )
    opt_state = jax.eval_shape(optimizer.init, params)
    return params, opt_state


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
