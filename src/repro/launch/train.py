"""Fault-tolerant HGC training driver (deliverable b's end-to-end path).

Single-host reference implementation of the full production loop:
  * JNCSS plans the coding scheme from the cluster model (or --s_e/--s_w
    fixes it); the HGC code builds the data-part assignment,
  * every iteration simulates/observes the straggler pattern, computes
    the collapsed decode weights λ, and feeds each *worker group's*
    examples with weights = coding coefficient × λ (the gradient of the
    weighted loss is the decoded full-batch gradient — exact under any
    tolerated pattern; verified by tests/test_train_integration.py),
  * checkpoint/restart: atomic saves + exact data-iterator resume,
  * straggler detection: observed delays update the runtime model and
    periodically re-plan via JNCSS (elastic).

On a TPU cluster the same step function runs under pjit with the mesh
and shardings of launch/dryrun.py; here batch dims stay on one device.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --scheme hgc_jncss
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore, config_hash
from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core import jncss as jncss_mod
from repro.core.hgc import HGCCode
from repro.core.runtime_model import ClusterParams, paper_cluster
from repro.core.topology import Tolerance, Topology
from repro.core import tradeoff
from repro.data.pipeline import TokenStream
from repro.dist.elastic import StragglerDetector, replan
from repro.launch import steps as steps_lib
from repro.optim import make_optimizer
from repro.models import transformer as tf


@dataclasses.dataclass
class HGCTrainState:
    params: object
    opt_state: object
    step: int


def _sample_straggler_pattern(rng, code: HGCCode, params: ClusterParams,
                              D: float):
    """Sample runtimes, wait per the HGC rule, return (fast_e, fast_w, T)."""
    wt, eu, _ = params.sample_iteration(rng, D)
    topo = code.topo
    s_e, s_w = code.tol.s_e, code.tol.s_w
    edge_T = np.empty(topo.n)
    fast_w = []
    off = 0
    for i in range(topo.n):
        mi = topo.m[i]
        order = np.argsort(wt[off : off + mi])[: mi - s_w]
        edge_T[i] = eu[i] + wt[off + order[-1]]
        fast_w.append(tuple(sorted(order.tolist())))
        off += mi
    eorder = np.argsort(edge_T)[: topo.n - s_e]
    fast_e = tuple(sorted(eorder.tolist()))
    return fast_e, fast_w, float(edge_T[eorder[-1]]), wt


def build_coded_batch(code: HGCCode, streams, fast_e, fast_w, seq_len):
    """Global batch = all workers' assigned-part examples, weighted by
    coeff × λ.  Straggling workers get weight 0 (their rows still flow
    through the step fn — shapes are static, only weights change)."""
    lam = code.collapsed_weights(fast_e, fast_w)
    tokens, targets, weights = [], [], []
    topo = code.topo
    for i in range(topo.n):
        for j in range(topo.m[i]):
            w_idx = topo.flat_index(i, j)
            coeff = code.worker_coeffs(i, j)
            for k in code.assignment.worker_parts(i, j):
                b = streams[k].next_batch()
                tokens.append(b["tokens"])
                targets.append(b["targets"])
                weights.append(
                    b["weights"] * float(coeff[k]) * float(lam[w_idx])
                )
    B = len(tokens)
    return {
        "tokens": np.concatenate(tokens, 0),
        "targets": np.concatenate(targets, 0),
        "weights": np.concatenate(weights, 0),
        # fixed normalizer keeps the loss linear in the weights (exact
        # coded decode); K parts × per-part token count
        "denom": np.float32(
            code.K * tokens[0].shape[0] * seq_len
        ),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b",
                    choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--part-batch", type=int, default=1,
                    help="examples per dataset part per iteration")
    ap.add_argument("--scheme", default="hgc_jncss",
                    choices=["hgc", "hgc_jncss", "uncoded"])
    ap.add_argument("--s-e", type=int, default=1)
    ap.add_argument("--s-w", type=int, default=1)
    ap.add_argument("--n-edges", type=int, default=2)
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--K", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--replan-every", type=int, default=0,
                    help="re-run JNCSS from observed delays every N steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    topo = Topology.uniform(args.n_edges, args.n_workers)
    rng_np = np.random.default_rng(args.seed)
    cluster = ClusterParams.homogeneous(
        topo, c=10.0, gamma=0.05, tau_w=50.0, p_w=0.2, tau_e=100.0,
        p_e=0.1,
    )
    # plan the code
    if args.scheme == "hgc_jncss":
        K = args.K or tradeoff.compatible_K(
            topo, Tolerance(args.s_e, args.s_w), at_least=topo.total_workers
        )
        plan = replan(cluster, K, seed=args.seed)
        code = plan.code
        print(f"[train] JNCSS chose (s_e={code.tol.s_e}, "
              f"s_w={code.tol.s_w}), D={code.load}, K={code.K}, "
              f"T̂={plan.expected_iteration_ms:.0f} ms")
    else:
        tol = Tolerance(
            0 if args.scheme == "uncoded" else args.s_e,
            0 if args.scheme == "uncoded" else args.s_w,
        )
        K = args.K or tradeoff.compatible_K(
            topo, tol, at_least=topo.total_workers
        )
        code = HGCCode.build(topo, tol, K=K, seed=args.seed)
        print(f"[train] fixed scheme {args.scheme}: (s_e={tol.s_e}, "
              f"s_w={tol.s_w}), D={code.load}, K={K}")

    tcfg = TrainConfig(
        optimizer=args.optimizer, lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1), grad_clip=1.0,
        scheme=args.scheme, s_e=code.tol.s_e, s_w=code.tol.s_w, K=code.K,
    )
    optimizer = make_optimizer(args.optimizer)
    train_step = jax.jit(
        steps_lib.make_train_step(cfg, tcfg, optimizer=optimizer)
    )

    # data: one resumable stream per dataset part
    streams = [
        TokenStream(cfg.vocab, args.part_batch, args.seq_len,
                    seed=args.seed * 1000 + k)
        for k in range(code.K)
    ]

    # init / resume
    rng = jax.random.PRNGKey(args.seed)
    params = tf.init_params(rng, cfg)
    opt_state = optimizer.init(params)
    start = 0
    store = None
    if args.checkpoint_dir:
        # hash the MODEL config only: run hyperparameters (total_steps,
        # lr schedule) legitimately change across restarts
        store = CheckpointStore(
            args.checkpoint_dir, keep=3, cfg_hash=config_hash(cfg),
        )
        if args.resume and store.latest_step() is not None:
            start, state, extra = store.restore()
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt_state"])
            for k, s in enumerate(streams):
                s.load_state_dict(extra["streams"][k])
            print(f"[train] resumed from step {start}")

    detector = StragglerDetector(cluster)
    t0 = time.time()
    sim_ms = 0.0
    for step in range(start, args.steps):
        fast_e, fast_w, t_iter, wt = _sample_straggler_pattern(
            rng_np, code, cluster, code.load
        )
        detector.observe(wt)
        sim_ms += t_iter
        batch = build_coded_batch(
            code, streams, fast_e, fast_w, args.seq_len
        )
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = train_step(
            params, opt_state, batch, jnp.asarray(step)
        )
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"sim_iter {t_iter:.0f} ms "
                  f"stragglers: edges={sorted(set(range(topo.n)) - set(fast_e))}")
        if store and (step + 1) % args.checkpoint_every == 0:
            store.save(
                step + 1,
                {"params": params, "opt_state": opt_state},
                extra={"streams": [s.state_dict() for s in streams]},
            )
        if args.replan_every and (step + 1) % args.replan_every == 0:
            plan = replan(detector.updated_params(code.load), code.K,
                          seed=args.seed)
            if (plan.tol.s_e, plan.tol.s_w) != (code.tol.s_e, code.tol.s_w):
                print(f"[train] replan: tolerance → (s_e={plan.tol.s_e}, "
                      f"s_w={plan.tol.s_w}), K={plan.K}, "
                      f"T̂={plan.expected_iteration_ms:.0f} ms")
                code = plan.code
                # the compatible K for the new tolerance may exceed the
                # old one — add resumable streams for the new parts
                while len(streams) < code.K:
                    streams.append(
                        TokenStream(cfg.vocab, args.part_batch, args.seq_len,
                                    seed=args.seed * 1000 + len(streams))
                    )
    wall = time.time() - t0
    print(f"[train] done: {args.steps - start} steps in {wall:.1f}s wall, "
          f"{sim_ms/1e3:.1f}s simulated cluster time")
    return params


if __name__ == "__main__":
    main()
