"""Fault-tolerant HGC training driver CLI.

Thin front-end over the public object model (:mod:`repro.api`): flags →
``CodedCluster`` + planner strategy + ``CodedSession`` → ``fit()``.
The three ``--dist`` aggregation modes are session policies:

  * ``off`` — single-host reference loop: λ rides the per-example batch
    weights (coeff × λ) and the jit gradient reduction decodes the coded
    aggregate implicitly,
  * ``coded`` — mesh-aware loop on a (pod, data[, model]) device mesh
    with the two-stage coded decode (eqs. 25/27) as real shard_map
    collectives, λ as a runtime operand (drops/replans never recompile),
  * ``coded_int8`` — same, with the bandwidth-limited edge→master hop
    quantized to blockwise int8 + error feedback.

Common to all modes: JNCSS plans the coding scheme from the cluster
model (or --s_e/--s_w fixes it); every iteration simulates/observes the
straggler pattern; checkpoints are atomic and carry the data-iterator
state, the straggler detector's EWMA buffers, the deployed (tolerance,
K) and the EF residuals — a killed-and-resumed run replans from
*observed* delays and reproduces the uninterrupted run bit-for-bit.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --scheme hgc_jncss
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 4 --dist coded_int8
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.api import CodedCluster, CodedSession, planner_for_scheme
# back-compat re-exports: these moved to repro.api (tests and user code
# imported them from here)
from repro.api.cluster import sample_straggler_pattern as \
    _sample_straggler_pattern_impl
from repro.api.session import (  # noqa: F401
    _extend_streams,
    _step_rng,
    build_coded_batch,
)
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.topology import Topology
from repro.launch.steps import _warn_once


def _sample_straggler_pattern(rng, code, params, D):
    """Back-compat alias of :func:`repro.api.sample_straggler_pattern`."""
    return _sample_straggler_pattern_impl(rng, code, params, D)


def _make_cluster(kind: str, topo: Topology):
    """Deprecated — use :meth:`repro.api.CodedCluster.homogeneous` /
    :meth:`~repro.api.CodedCluster.hetero` (this shim returns the bare
    ``ClusterParams`` those constructors wrap)."""
    _warn_once("train._make_cluster",
               "repro.api.CodedCluster.homogeneous / .hetero")
    ctor = CodedCluster.hetero if kind == "hetero" \
        else CodedCluster.homogeneous
    return ctor(topo=topo).params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b",
                    choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--part-batch", type=int, default=1,
                    help="examples per dataset part per iteration")
    ap.add_argument("--scheme", default="hgc_jncss",
                    choices=["hgc", "hgc_jncss", "uncoded",
                             "hgc_grouped", "hgc_comm"],
                    help="planning strategy (see docs/planners.md): "
                         "hgc_jncss=Algorithm 2, hgc=fixed (s_e,s_w), "
                         "uncoded=no redundancy, hgc_grouped=per-edge "
                         "worker tolerances, hgc_comm=message-budgeted")
    ap.add_argument("--s-e", type=int, default=1)
    ap.add_argument("--s-w", type=int, default=1)
    ap.add_argument("--n-edges", type=int, default=2)
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--cluster", default="homogeneous",
                    choices=["homogeneous", "hetero"],
                    help="simulated cluster model (hetero: one slow "
                         "edge — JNCSS then plans real edge tolerance)")
    ap.add_argument("--K", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--dist", default="off",
                    choices=["off", "coded", "coded_int8", "coded_q"],
                    help="aggregation execution mode: single-host "
                         "reference, shard_map coded collectives, "
                         "coded with the int8+EF cross-pod hop, or "
                         "coded_q with the codec --grad-compression "
                         "picks (int8 | int4 | fp8)")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="'model' mesh axis size (--dist modes): real "
                         "in-shard_map tensor parallelism — params/opt-"
                         "state shard over it AND the forward/backward "
                         "runs Megatron-style column/row-parallel with "
                         "psums over 'model'")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel degree override (0 = use "
                         "--model-shards).  Validated against the arch "
                         "config's divisibility constraints up front — "
                         "a clear error instead of a shape crash")
    ap.add_argument("--seq-shard", dest="seq_shard",
                    action="store_const", const=True, default=None,
                    help="sequence parallelism inside the dist-TP "
                         "shard_map: activations between the TP "
                         "collective pairs shard over 'model' along "
                         "seq (reduce-scatter/all-gather instead of "
                         "all-reduce — tp x less activation state at "
                         "identical collective bytes).  Needs --tp > 1 "
                         "and seq-len divisible by tp; composes with "
                         "--dist coded_int8.  Default: the "
                         "TrainConfig.seq_shard_activations config "
                         "value")
    ap.add_argument("--no-seq-shard", dest="seq_shard",
                    action="store_const", const=False,
                    help="force sequence parallelism off (overrides "
                         "the config-level default)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline-parallel stage count (--dist modes): "
                         "a leading 'stage' mesh axis shards the "
                         "stacked layer groups and the train step runs "
                         "a microbatched ppermute pipeline inside the "
                         "same shard_map as the coded decode.  Needs "
                         "n_layers//len(block_pattern) divisible by "
                         "the stage count; composes with --tp, "
                         "--seq-shard and --dist coded_int8")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="pipeline microbatch count per step (0 = one "
                         "per stage, the minimum that fills the "
                         "pipeline).  Must divide the per-group coded "
                         "batch rows (load D × --part-batch)")
    ap.add_argument("--grad-block", type=int, default=64,
                    help="quantization block size on the edge→master "
                         "hop (any codec)")
    ap.add_argument("--grad-compression", default="",
                    choices=["", "int8", "int4", "fp8"],
                    help="cross-pod codec for --dist coded_q "
                         "(default int8): int8/fp8 cut the hop bytes "
                         "4x, packed int4 8x; all share the EF "
                         "residual contract, so kill/resume and "
                         "replans behave identically")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--stop-after", type=int, default=0,
                    help="simulate a kill: exit cleanly after N steps "
                         "without touching the LR schedule (--steps "
                         "still sets total_steps, so a later --resume "
                         "run reproduces the uninterrupted trajectory)")
    ap.add_argument("--replan-every", type=int, default=0,
                    help="re-run JNCSS from observed delays every N steps")
    ap.add_argument("--force-drop-edge", type=int, default=-1,
                    help="force this edge to straggle at --force-drop-step")
    ap.add_argument("--force-drop-step", type=int, default=-1)
    ap.add_argument("--metrics-out", default="",
                    help="write per-step losses + jit cache stats as JSON")
    ap.add_argument("--expect-zero-recompile", action="store_true",
                    help="exit 1 if the train step compiled more than once")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    tp = args.tp or args.model_shards
    if args.dist == "off" and tp > 1:
        raise SystemExit("--tp requires a --dist mode (the single-host "
                         "reference loop has no model mesh axis)")
    if args.dist == "off" and args.pp > 1:
        raise SystemExit("--pp requires a --dist mode (the pipeline "
                         "runs over the 'stage' mesh axis inside "
                         "shard_map)")
    ctor = CodedCluster.hetero if args.cluster == "hetero" \
        else CodedCluster.homogeneous
    try:
        session = CodedSession(
            ctor(args.n_edges, args.n_workers),
            cfg,
            planner=planner_for_scheme(args.scheme, args.s_e, args.s_w),
            mode=args.dist,
            tp=tp,
            seq_shard=args.seq_shard,
            pp=args.pp,
            microbatches=args.microbatches,
            seq_len=args.seq_len,
            part_batch=args.part_batch,
            K=args.K,
            optimizer=args.optimizer,
            lr=args.lr,
            total_steps=args.steps,
            grad_block=args.grad_block,
            grad_compression=args.grad_compression,
            seed=args.seed,
            scheme=args.scheme,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
            resume=args.resume,
            log_every=args.log_every,
        )
    except ValueError as e:
        raise SystemExit(f"[train] {e}")
    report = session.fit(
        args.steps,
        replan_every=args.replan_every,
        force_drop_edge=args.force_drop_edge,
        force_drop_step=args.force_drop_step,
        stop_after=args.stop_after,
    )
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(report, f, indent=1)
    if args.expect_zero_recompile:
        cache_entries = report["jit_cache_entries"]
        if cache_entries == -1:
            # private jax API unavailable on this version — can't
            # verify, but absence of the counter is not a recompile
            print("[train] WARNING: jit cache size unavailable on this "
                  "jax; zero-recompile check skipped", file=sys.stderr)
        elif cache_entries != 1:
            print(f"[train] FAIL: expected exactly 1 jit cache entry "
                  f"(zero recompiles), found {cache_entries}",
                  file=sys.stderr)
            sys.exit(1)
    return session.params


if __name__ == "__main__":
    main()
