"""Fault-tolerant HGC training driver (deliverable b's end-to-end path).

The full production loop, runnable in three aggregation modes
(``--dist``):

  * ``off`` — single-host reference loop: λ rides the per-example batch
    weights (coeff × λ) and the jit gradient reduction decodes the coded
    aggregate implicitly,
  * ``coded`` — mesh-aware loop on a (pod, data[, model]) device mesh:
    params/opt-state are sharded by ``dist.sharding`` rules, each
    (pod, data) shard group computes its encoded message G_ij (eq. 22)
    from its own batch slice, and ``dist.grad_sync`` runs the two-stage
    coded decode (eqs. 25/27) as real shard_map collectives with λ as a
    runtime operand — straggler drops and replans never recompile,
  * ``coded_int8`` — same, with the bandwidth-limited edge→master hop
    quantized to blockwise int8 + error feedback (``dist.compression``);
    the per-pod EF residuals are part of the training state.

Common to all modes: JNCSS plans the coding scheme from the cluster
model (or --s_e/--s_w fixes it); every iteration simulates/observes the
straggler pattern; checkpoints are atomic and carry the data-iterator
state, the straggler detector's EWMA buffers, the deployed (tolerance,
K) and the EF residuals — a killed-and-resumed run replans from
*observed* delays and reproduces the uninterrupted run bit-for-bit.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --scheme hgc_jncss
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 4 --dist coded_int8
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore, config_hash
from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.hgc import HGCCode
from repro.core.runtime_model import ClusterParams
from repro.core.topology import Tolerance, Topology
from repro.core import tradeoff
from repro.data.pipeline import TokenStream
from repro.dist.elastic import StragglerDetector, replan
from repro.launch import steps as steps_lib
from repro.optim import make_optimizer
from repro.models import transformer as tf


@dataclasses.dataclass
class HGCTrainState:
    params: object
    opt_state: object
    step: int


def _sample_straggler_pattern(rng, code: HGCCode, params: ClusterParams,
                              D: float):
    """Sample runtimes, wait per the HGC rule, return (fast_e, fast_w, T)."""
    wt, eu, _ = params.sample_iteration(rng, D)
    topo = code.topo
    s_e, s_w = code.tol.s_e, code.tol.s_w
    edge_T = np.empty(topo.n)
    fast_w = []
    off = 0
    for i in range(topo.n):
        mi = topo.m[i]
        order = np.argsort(wt[off : off + mi])[: mi - s_w]
        edge_T[i] = eu[i] + wt[off + order[-1]]
        fast_w.append(tuple(sorted(order.tolist())))
        off += mi
    eorder = np.argsort(edge_T)[: topo.n - s_e]
    fast_e = tuple(sorted(eorder.tolist()))
    return fast_e, fast_w, float(edge_T[eorder[-1]]), wt


def _step_rng(seed: int, step: int) -> np.random.Generator:
    """Per-step straggler RNG: resume replays the exact pattern sequence
    (bit-for-bit kill/resume needs history-independent sampling)."""
    return np.random.default_rng(np.random.SeedSequence([seed, 7919, step]))


def build_coded_batch(code: HGCCode, streams, fast_e, fast_w, seq_len,
                      with_lam: bool = True):
    """Global batch = all workers' assigned-part examples.

    ``with_lam=True`` (single-host path): weights carry coeff × λ so the
    jit gradient reduction decodes implicitly; straggling workers get
    weight 0 (their rows still flow through the step fn — shapes are
    static, only weights change).  ``with_lam=False`` (``--dist``
    paths): weights carry the coding coefficients only — λ is applied
    inside the shard_map decode, per shard group.  Example order is
    (pod, data)-major either way, so sharding the batch dim over
    ("pod", "data") hands worker (i, j) exactly its own examples.
    """
    lam = code.collapsed_weights(fast_e, fast_w) if with_lam else None
    tokens, targets, weights = [], [], []
    topo = code.topo
    for i in range(topo.n):
        for j in range(topo.m[i]):
            w_idx = topo.flat_index(i, j)
            coeff = code.worker_coeffs(i, j)
            for k in code.assignment.worker_parts(i, j):
                b = streams[k].next_batch()
                tokens.append(b["tokens"])
                targets.append(b["targets"])
                w = b["weights"] * float(coeff[k])
                if lam is not None:
                    w = w * float(lam[w_idx])
                weights.append(w)
    return {
        "tokens": np.concatenate(tokens, 0),
        "targets": np.concatenate(targets, 0),
        "weights": np.concatenate(weights, 0),
        # fixed normalizer keeps the loss linear in the weights (exact
        # coded decode); K parts × per-part token count
        "denom": np.float32(
            code.K * tokens[0].shape[0] * seq_len
        ),
    }


def _make_cluster(kind: str, topo: Topology) -> ClusterParams:
    """The simulated cluster the JNCSS planner prices.

    ``homogeneous`` — every node identical (coding rarely pays off:
    JNCSS correctly picks (0, 0) because tolerating an edge only raises
    the load).  ``hetero`` — the last edge is a Type-III-style straggler
    (slow, loss-prone uplink, paper §V-A flavor): the regime where JNCSS
    actually buys edge tolerance (s_e ≥ 1).
    """
    base = ClusterParams.homogeneous(
        topo, c=10.0, gamma=0.05, tau_w=50.0, p_w=0.2, tau_e=100.0,
        p_e=0.1,
    )
    if kind == "homogeneous":
        return base
    tau_e = base.tau_e.copy()
    p_e = base.p_e.copy()
    tau_e[-1] = 2000.0
    p_e[-1] = 0.4
    return dataclasses.replace(base, tau_e=tau_e, p_e=p_e)


def _extend_streams(streams, K: int, vocab: int, part_batch: int,
                    seq_len: int, seed: int):
    """K growth (replan / restored checkpoint) REUSES the existing part
    streams — only the new parts get fresh resumable streams."""
    while len(streams) < K:
        streams.append(
            TokenStream(vocab, part_batch, seq_len,
                        seed=seed * 1000 + len(streams))
        )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b",
                    choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--part-batch", type=int, default=1,
                    help="examples per dataset part per iteration")
    ap.add_argument("--scheme", default="hgc_jncss",
                    choices=["hgc", "hgc_jncss", "uncoded"])
    ap.add_argument("--s-e", type=int, default=1)
    ap.add_argument("--s-w", type=int, default=1)
    ap.add_argument("--n-edges", type=int, default=2)
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--cluster", default="homogeneous",
                    choices=["homogeneous", "hetero"],
                    help="simulated cluster model (hetero: one slow "
                         "edge — JNCSS then plans real edge tolerance)")
    ap.add_argument("--K", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--dist", default="off",
                    choices=["off", "coded", "coded_int8"],
                    help="aggregation execution mode: single-host "
                         "reference, shard_map coded collectives, or "
                         "coded with the int8+EF cross-pod hop")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="'model' mesh axis size (--dist modes): real "
                         "in-shard_map tensor parallelism — params/opt-"
                         "state shard over it AND the forward/backward "
                         "runs Megatron-style column/row-parallel with "
                         "psums over 'model'")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel degree override (0 = use "
                         "--model-shards).  Validated against the arch "
                         "config's divisibility constraints up front — "
                         "a clear error instead of a shape crash")
    ap.add_argument("--grad-block", type=int, default=64,
                    help="int8 block size on the edge→master hop")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--stop-after", type=int, default=0,
                    help="simulate a kill: exit cleanly after N steps "
                         "without touching the LR schedule (--steps "
                         "still sets total_steps, so a later --resume "
                         "run reproduces the uninterrupted trajectory)")
    ap.add_argument("--replan-every", type=int, default=0,
                    help="re-run JNCSS from observed delays every N steps")
    ap.add_argument("--force-drop-edge", type=int, default=-1,
                    help="force this edge to straggle at --force-drop-step")
    ap.add_argument("--force-drop-step", type=int, default=-1)
    ap.add_argument("--metrics-out", default="",
                    help="write per-step losses + jit cache stats as JSON")
    ap.add_argument("--expect-zero-recompile", action="store_true",
                    help="exit 1 if the train step compiled more than once")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    topo = Topology.uniform(args.n_edges, args.n_workers)
    cluster = _make_cluster(args.cluster, topo)
    # plan the code
    if args.scheme == "hgc_jncss":
        K = args.K or tradeoff.compatible_K(
            topo, Tolerance(args.s_e, args.s_w), at_least=topo.total_workers
        )
        plan = replan(cluster, K, seed=args.seed)
        code = plan.code
        print(f"[train] JNCSS chose (s_e={code.tol.s_e}, "
              f"s_w={code.tol.s_w}), D={code.load}, K={code.K}, "
              f"T̂={plan.expected_iteration_ms:.0f} ms")
    else:
        tol = Tolerance(
            0 if args.scheme == "uncoded" else args.s_e,
            0 if args.scheme == "uncoded" else args.s_w,
        )
        K = args.K or tradeoff.compatible_K(
            topo, tol, at_least=topo.total_workers
        )
        code = HGCCode.build(topo, tol, K=K, seed=args.seed)
        print(f"[train] fixed scheme {args.scheme}: (s_e={tol.s_e}, "
              f"s_w={tol.s_w}), D={code.load}, K={K}")

    tcfg = TrainConfig(
        optimizer=args.optimizer, lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1), grad_clip=1.0,
        scheme=args.scheme, s_e=code.tol.s_e, s_w=code.tol.s_w, K=code.K,
        dist_mode=args.dist,
        grad_compression="int8" if args.dist == "coded_int8" else "none",
        grad_compression_block=args.grad_block,
    )
    optimizer = make_optimizer(args.optimizer)

    # mesh (--dist modes); imports stay lazy so the single-host path
    # never touches jax.sharding machinery
    mesh = None
    model_shards = args.tp or args.model_shards
    if args.dist != "off":
        from repro.dist import grad_sync
        from repro.dist.mesh import make_test_mesh
        from repro.dist.sharding import validate_tp

        validate_tp(cfg, model_shards)
        mesh = make_test_mesh(args.n_edges, args.n_workers, model_shards)
        print(f"[train] dist={args.dist}: mesh "
              f"(pod={args.n_edges} × data={args.n_workers} × "
              f"model={model_shards}), "
              f"grad_compression={tcfg.grad_compression}"
              + (f", TP degree {model_shards}" if model_shards > 1 else ""))
    elif args.tp > 1:
        raise SystemExit("--tp requires a --dist mode (the single-host "
                         "reference loop has no model mesh axis)")

    # data: one resumable stream per dataset part
    streams = []
    _extend_streams(streams, code.K, cfg.vocab, args.part_batch,
                    args.seq_len, args.seed)

    # init / resume
    rng = jax.random.PRNGKey(args.seed)
    params = tf.init_params(rng, cfg)
    opt_state = optimizer.init(params)
    detector = StragglerDetector(cluster)
    start = 0
    store = None
    restored_extra: Dict = {}
    if args.checkpoint_dir:
        # hash the MODEL config only: run hyperparameters (total_steps,
        # lr schedule) legitimately change across restarts
        store = CheckpointStore(
            args.checkpoint_dir, keep=3, cfg_hash=config_hash(cfg),
        )
        if args.resume and store.latest_step() is not None:
            start, state, restored_extra = store.restore()
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt_state"])
            ck = restored_extra.get("code")
            if ck and (ck["s_e"], ck["s_w"], ck["K"]) != (
                    code.tol.s_e, code.tol.s_w, code.K):
                # the run had replanned before the kill — rebuild the
                # deployed code deterministically (same seed ⇒ same code)
                code = HGCCode.build(
                    topo, Tolerance(ck["s_e"], ck["s_w"]), K=ck["K"],
                    seed=args.seed,
                )
                print(f"[train] restored replanned code "
                      f"(s_e={ck['s_e']}, s_w={ck['s_w']}, K={ck['K']})")
            saved_streams = restored_extra["streams"]
            # the saved list may exceed code.K (a replan once grew K and
            # later shrank it — streams are never discarded)
            _extend_streams(streams, max(code.K, len(saved_streams)),
                            cfg.vocab, args.part_batch, args.seq_len,
                            args.seed)
            for k, sd in enumerate(saved_streams):
                streams[k].load_state_dict(sd)
            if "detector" in restored_extra:
                detector.load_state_dict(restored_extra["detector"])
            print(f"[train] resumed from step {start}")

    # shard the training state onto the mesh, set up λ / EF residuals,
    # and jit the step with PINNED output shardings — outputs land in
    # exactly the input layouts, so step 2 reuses step 1's executable
    # (the zero-recompile invariant)
    residual: Dict = {}
    batch_sh = lam_sh = None
    if mesh is None:
        train_step = jax.jit(
            steps_lib.make_train_step(cfg, tcfg, optimizer=optimizer)
        )
    else:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.dist import compression as comp_lib
        from repro.dist import sharding as shard_lib

        param_sh, opt_sh = shard_lib.state_shardings(
            params, opt_state, cfg, mesh, fsdp=tcfg.fsdp,
            head_aligned=True,
        )
        params = jax.device_put(params, param_sh)
        opt_state = jax.device_put(opt_state, opt_sh)
        dp = ("pod", "data")
        batch_sh = {
            "tokens": NamedSharding(mesh, P(dp, None)),
            "targets": NamedSharding(mesh, P(dp, None)),
            "weights": NamedSharding(mesh, P(dp, None)),
            "denom": NamedSharding(mesh, P()),
        }
        lam_sh = NamedSharding(mesh, P("pod", "data"))
        res_sh: Dict = {}
        if tcfg.grad_compression == "int8":
            if "ef_residual" in restored_extra:
                residual = jax.tree.map(
                    jnp.asarray, restored_extra["ef_residual"]
                )
            else:
                residual = comp_lib.init_pod_residuals(params, args.n_edges)
            # under TP the residual follows its gradient leaf onto the
            # model axis (same pspec rules as the step's shard_map)
            res_sh = shard_lib.to_shardings(
                shard_lib.residual_pspecs(params, cfg, mesh,
                                          fsdp=tcfg.fsdp),
                mesh,
            )
            residual = jax.device_put(residual, res_sh)
        train_step = jax.jit(
            steps_lib.make_dist_train_step(cfg, tcfg, mesh,
                                           optimizer=optimizer),
            out_shardings=(param_sh, opt_sh, res_sh,
                           NamedSharding(mesh, P())),
        )

    def save_checkpoint(step):
        extra = {
            "streams": [s.state_dict() for s in streams],
            "detector": detector.state_dict(),
            "code": {"s_e": code.tol.s_e, "s_w": code.tol.s_w,
                     "K": code.K},
        }
        if tcfg.grad_compression == "int8" and mesh is not None:
            extra["ef_residual"] = residual
        store.save(
            step, {"params": params, "opt_state": opt_state}, extra=extra
        )

    t0 = time.time()
    sim_ms = 0.0
    losses = []
    steps_done = 0
    for step in range(start, args.steps):
        steps_done += 1
        fast_e, fast_w, t_iter, wt = _sample_straggler_pattern(
            _step_rng(args.seed, step), code, cluster, code.load
        )
        if step == args.force_drop_step and \
                0 <= args.force_drop_edge < topo.n and code.tol.s_e > 0:
            # forced straggler drop: exercise the zero-recompile claim —
            # only the λ operand changes, never the compiled step
            fast_e = tuple(
                i for i in range(topo.n) if i != args.force_drop_edge
            )[: topo.n - code.tol.s_e]
        detector.observe(wt)
        sim_ms += t_iter
        batch = build_coded_batch(
            code, streams, fast_e, fast_w, args.seq_len,
            with_lam=(mesh is None),
        )
        if mesh is None:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = train_step(
                params, opt_state, batch, jnp.asarray(step)
            )
        else:
            batch = {
                k: jax.device_put(jnp.asarray(v), batch_sh[k])
                for k, v in batch.items()
            }
            lam_arr = jax.device_put(
                jnp.asarray(grad_sync.lam_array_from_code(
                    code, fast_e, fast_w, args.n_edges, args.n_workers
                )),
                lam_sh,
            )
            params, opt_state, residual, metrics = train_step(
                params, opt_state, batch, lam_arr, residual,
                jnp.asarray(step),
            )
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"sim_iter {t_iter:.0f} ms "
                  f"stragglers: edges={sorted(set(range(topo.n)) - set(fast_e))}")
        if args.replan_every and (step + 1) % args.replan_every == 0:
            plan = replan(detector.updated_params(code.load), code.K,
                          seed=args.seed, reuse=code)
            if plan.code is not code:
                print(f"[train] replan: tolerance → (s_e={plan.tol.s_e}, "
                      f"s_w={plan.tol.s_w}), K={plan.K}, "
                      f"T̂={plan.expected_iteration_ms:.0f} ms")
                code = plan.code
                # the compatible K for the new tolerance may exceed the
                # old one — existing part streams are reused, only the
                # new parts get streams
                _extend_streams(streams, code.K, cfg.vocab,
                                args.part_batch, args.seq_len, args.seed)
        # checkpoint AFTER a possible replan so the saved (tolerance, K)
        # is what the surviving run would actually train with
        if store and (step + 1) % args.checkpoint_every == 0:
            save_checkpoint(step + 1)
        if args.stop_after and step + 1 >= args.stop_after:
            print(f"[train] stopping after step {step} (simulated kill)")
            break

    cache_entries = -1
    size_fn = getattr(train_step, "_cache_size", None)
    if callable(size_fn):
        cache_entries = int(size_fn())
    wall = time.time() - t0
    print(f"[train] done: {steps_done} steps in {wall:.1f}s wall, "
          f"{sim_ms/1e3:.1f}s simulated cluster time, "
          f"jit cache entries: {cache_entries}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({
                "dist": args.dist,
                "first_step": start,
                "losses": losses,
                "jit_cache_entries": cache_entries,
            }, f, indent=1)
    if args.expect_zero_recompile:
        if cache_entries == -1:
            # private jax API unavailable on this version — can't
            # verify, but absence of the counter is not a recompile
            print("[train] WARNING: jit cache size unavailable on this "
                  "jax; zero-recompile check skipped", file=sys.stderr)
        elif cache_entries != 1:
            print(f"[train] FAIL: expected exactly 1 jit cache entry "
                  f"(zero recompiles), found {cache_entries}",
                  file=sys.stderr)
            sys.exit(1)
    return params


if __name__ == "__main__":
    main()
