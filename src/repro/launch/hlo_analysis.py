"""HLO roofline analyzer.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE — a
``lax.scan`` body (layer stack, microbatch accumulation, KV-chunk scan)
is under-counted by its trip count, which under-reports FLOPs by ~100×
on our scanned models.  This module parses post-optimization HLO text,
walks the call graph, and multiplies loop bodies by their
``backend_config known_trip_count`` — yielding faithful per-device:

  * FLOPs           (dot: 2·|out|·contracted, conv approx, elementwise),
  * bytes accessed  (boundary reads+writes; fusion bodies are free),
  * collective operand/link bytes per class, split ICI vs cross-pod.

This is the profiler of the dry-run (no real TPU): §Roofline terms and
the §Perf iteration loop read from here.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}
_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|"
    r"pred|c64|c128)\[([0-9,]*)\]"
)
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops that are pure views / metadata — no data movement
_NOCOST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "token", "partition-id", "replica-id", "domain",
    "opt-barrier",
}
# attention-einsum signatures in op_name metadata (fwd + bwd forms)
_ATTN_SIG = ("bskgd,btkd", "bkgst,btkd", "bkgsd,btkd", "bkgst,bskgd",
             "bkgst,bkgsd")

def _is_attn(line: str) -> bool:
    return any(sig in line for sig in _ATTN_SIG)


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "power", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign",
    "cosine", "sine", "floor", "ceil", "round-nearest-afz", "clamp",
    "select", "compare", "and", "or", "xor", "not", "atan2", "remainder",
    "erf",
}


def _nelems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n


def _shapes_in(text: str) -> List[Tuple[str, int, int, int]]:
    """All (dtype, nelems, bytes, bf16eq_bytes) shape tokens."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        n = _nelems(dims)
        b = n * _DTYPE_BYTES[dt]
        beq = n * min(_DTYPE_BYTES[dt], 2) if dt in ("f32", "f64") else b
        out.append((dt, n, b, beq))
    return out


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_bytes: int
    out_elems: int
    operands: List[str]
    line: str
    root: bool = False
    out_bytes_eq: int = 0  # f32 counted at 2 B (bf16-equivalent)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    # bf16-equivalent bytes: XLA:CPU float-normalization upcasts every
    # bf16 tensor to f32, inflating byte counts ~2× vs a TPU deployment
    # whose policy is bf16 activations/collectives.  These fields count
    # f32 elements at 2 bytes — the "intended dtype" lower estimate.
    bytes_bf16eq: float = 0.0
    # bf16eq bytes attributable to attention-score einsums — traffic a
    # fused Pallas flash kernel (kernels/flash_attention.py) retires in
    # VMEM on a real TPU.  memory_s_pallas = (bytes − attn)/HBM_BW.
    attn_bytes_bf16eq: float = 0.0
    coll: Optional[Dict] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {
                c: {"count": 0.0, "operand_bytes": 0.0, "output_bytes": 0.0,
                    "link_bytes": 0.0, "cross_pod_link_bytes": 0.0,
                    "link_bytes_bf16eq": 0.0}
                for c in COLLECTIVES
            }

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_bf16eq += other.bytes_bf16eq * mult
        self.attn_bytes_bf16eq += other.attn_bytes_bf16eq * mult
        for c in COLLECTIVES:
            for k in self.coll[c]:
                self.coll[c][k] += other.coll[c][k] * mult


def _parse_op_line(s: str):
    """'%name = <type> kind(operands), attrs' → (name, out_part, kind,
    args_str) or None.  Tuple types may contain /*index=N*/ comments, so
    the type is extracted with balanced-paren scanning, not a regex."""
    if " = " not in s:
        return None
    lhs, rhs = s.split(" = ", 1)
    name = lhs.strip()
    if name.startswith("ROOT "):
        name = name[5:]
    name = name.lstrip("%")
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        out_part, rest = rhs[: end + 1], rhs[end + 1 :].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        out_part, rest = rhs[:sp], rhs[sp + 1 :].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    kind = m.group(1)
    start = len(kind) + 1
    depth, i = 1, start
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    return name, out_part, kind, rest[start : i - 1]


def parse_module(text: str):
    """→ (computations: name → [Op], entry_name, fusion_comp_names)."""
    comps: Dict[str, List[Op]] = {}
    entry = None
    cur: Optional[str] = None
    fusion_comps = set()
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            # computation header: "%name (params…) -> result {"
            # (params may nest parens — match on the line's first token)
            if s.endswith("{") and "->" in s and " = " not in s:
                toks = s.split()
                i = 1 if toks[0] == "ENTRY" else 0
                if i < len(toks):
                    cur = toks[i].lstrip("%").split("(")[0]
                    comps[cur] = []
                    if toks[0] == "ENTRY":
                        entry = cur
                continue
        else:
            if s == "}":
                cur = None
                continue
            parsed = _parse_op_line(s)
            if parsed is None:
                continue
            name, out_part, kind, args = parsed
            shp = _shapes_in(out_part)
            out_b = sum(t[2] for t in shp)
            out_n = sum(t[1] for t in shp)
            out_beq = sum(t[3] for t in shp)
            operands = re.findall(r"%([\w.\-]+)", args)
            comps[cur].append(
                Op(name, kind, out_b, out_n, operands, s,
                   root=s.startswith("ROOT "), out_bytes_eq=out_beq)
            )
            if kind == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", s)
                if fm:
                    fusion_comps.add(fm.group(1))
    return comps, entry, fusion_comps


def _dot_flops(op: Op, sym: Dict[str, Op]) -> float:
    out_n = op.out_elems
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    lhs = sym.get(op.operands[0]) if op.operands else None
    contracted = 1
    if m and lhs is not None:
        lhs_shp = _SHAPE_RE.search(lhs.line.split(" = ", 1)[1])
        if lhs_shp:
            dims = [int(d) for d in lhs_shp.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contracted *= dims[int(ci)]
    return 2.0 * out_n * contracted


def _conv_flops(op: Op, sym: Dict[str, Op]) -> float:
    # approx: 2 · |out| · (kernel elems / out_features)
    if len(op.operands) < 2:
        return 2.0 * op.out_elems
    ker = sym.get(op.operands[1])
    if ker is None:
        return 2.0 * op.out_elems
    ksh = _SHAPE_RE.search(ker.line.split(" = ", 1)[1])
    if not ksh:
        return 2.0 * op.out_elems
    kd = [int(d) for d in ksh.group(2).split(",") if d]
    kelems = 1
    for d in kd:
        kelems *= d
    out_feat = max(kd[-1], 1)
    return 2.0 * op.out_elems * (kelems / out_feat)


def _classify_groups(line: str, pod_stride: int) -> bool:
    """True iff the collective spans devices ≥ pod_stride apart (DCN)."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        if ids and max(ids) - min(ids) >= pod_stride:
            return True
        return False
    # iota format: replica_groups=[8,64]<=[512] (reshape/transpose form)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]"
                  r"(?:T\(([\d,]+)\))?", line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        # contiguous groups of size gs: span = gs − 1 unless transposed
        if m.group(4):  # transposed iota — conservative: assume strided
            return gs * ng >= pod_stride * 2 or True if gs > 1 else False
        return gs - 1 >= pod_stride
    return False


def _fusion_traffic(fops: List[Op], attr: str = "out_bytes") -> float:
    """Approximate HBM traffic of one fusion execution.

    Reads: per inner parameter — if ALL its users slice it
    (slice/dynamic-slice/gather), only the slices move; else the full
    parameter moves.  Writes: the root's bytes, except a
    dynamic-update-slice root writes only the inserted update.
    """
    users: Dict[str, List[Op]] = {}
    for o in fops:
        for ref in o.operands:
            users.setdefault(ref, []).append(o)
    traffic = 0.0
    root_out = 0.0
    gb = lambda o: getattr(o, attr)
    for o in fops:
        if o.kind == "parameter":
            us = users.get(o.name, [])
            if us and all(
                u.kind in ("slice", "dynamic-slice", "gather")
                and u.operands and u.operands[0] == o.name
                for u in us
            ):
                traffic += sum(gb(u) for u in us)
            else:
                traffic += gb(o)
        if o.root:
            if o.kind == "dynamic-update-slice" and len(o.operands) > 1:
                sym = {x.name: x for x in fops}
                upd = sym.get(o.operands[1])
                root_out = gb(upd) if upd else gb(o)
            else:
                root_out = gb(o)
    return traffic + root_out


def analyze(text: str, pod_stride: int = 256) -> Costs:
    comps, entry, fusion_comps = parse_module(text)
    memo: Dict[str, Costs] = {}

    def comp_cost(cname: str, in_fusion: bool) -> Costs:
        key = cname + ("#f" if in_fusion else "")
        if key in memo:
            return memo[key]
        total = Costs()
        ops = comps.get(cname, [])
        sym = {o.name: o for o in ops}
        for op in ops:
            k = op.kind
            if k in _NOCOST:
                continue
            # ---- FLOPs ----
            if k == "dot":
                total.flops += _dot_flops(op, sym)
            elif k == "convolution":
                total.flops += _conv_flops(op, sym)
            elif k in _ELEMENTWISE:
                total.flops += op.out_elems
            elif k in ("reduce", "reduce-window"):
                in_n = sum(
                    sym[o].out_elems for o in op.operands if o in sym
                ) or op.out_elems
                total.flops += in_n
            # ---- bytes (boundary ops only; fusion bodies are fused) ----
            if not in_fusion:
                if k in ("dynamic-slice", "slice", "gather"):
                    # traffic = the slice moved, not the sliced-from buffer
                    total.bytes += 2 * op.out_bytes
                    total.bytes_bf16eq += 2 * op.out_bytes_eq
                    if _is_attn(op.line):
                        total.attn_bytes_bf16eq += 2 * op.out_bytes_eq
                elif k in ("dynamic-update-slice", "scatter"):
                    big = (sym[op.operands[1]]
                           if len(op.operands) > 1
                           and op.operands[1] in sym else op)
                    total.bytes += 2 * big.out_bytes
                    total.bytes_bf16eq += 2 * big.out_bytes_eq
                elif k not in ("while", "conditional", "call", "fusion"):
                    opnds = [sym[o] for o in op.operands if o in sym]
                    total.bytes += op.out_bytes + sum(
                        o.out_bytes for o in opnds)
                    beq = op.out_bytes_eq + sum(
                        o.out_bytes_eq for o in opnds)
                    total.bytes_bf16eq += beq
                    if _is_attn(op.line):
                        total.attn_bytes_bf16eq += beq
            # ---- collectives ----
            base = None
            for c in COLLECTIVES:
                if k == c or k.startswith(c + "-start"):
                    base = c
                    break
            if base is not None:
                in_b = sum(
                    sym[o].out_bytes for o in op.operands if o in sym
                )
                in_beq = sum(
                    sym[o].out_bytes_eq for o in op.operands if o in sym
                )
                cross = _classify_groups(op.line, pod_stride)
                st = total.coll[base]
                st["count"] += 1
                st["operand_bytes"] += in_b
                st["output_bytes"] += op.out_bytes
                link = 2 * in_b if base == "all-reduce" else (
                    op.out_bytes if base == "all-gather" else in_b
                )
                link_eq = 2 * in_beq if base == "all-reduce" else (
                    op.out_bytes_eq if base == "all-gather" else in_beq
                )
                st["link_bytes"] += link
                st["link_bytes_bf16eq"] += link_eq
                if cross:
                    st["cross_pod_link_bytes"] += link
            # ---- control flow / calls ----
            if k == "while":
                m = re.search(r"body=%?([\w.\-]+)", op.line)
                c = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
                trip = float(c.group(1)) if c else 1.0
                if m:
                    total.add(comp_cost(m.group(1), in_fusion), trip)
            elif k == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"true_computation=%?([\w.\-]+)|"
                    r"false_computation=%?([\w.\-]+))", op.line)
                names: List[str] = []
                for b in branches:
                    for part in b:
                        if part:
                            names.extend(
                                re.findall(r"%?([\w.\-]+)", part))
                if names:
                    worst = None
                    for nm in names:
                        cc = comp_cost(nm, in_fusion)
                        if worst is None or cc.flops > worst.flops:
                            worst = cc
                    if worst:
                        total.add(worst)
            elif k == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if m:
                    total.add(comp_cost(m.group(1), in_fusion))
            elif k == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.line)
                if m:
                    # FLOPs inside count; bytes: slice-aware boundary model
                    total.add(comp_cost(m.group(1), True))
                    if not in_fusion:
                        fops = comps.get(m.group(1), [])
                        total.bytes += _fusion_traffic(fops)
                        feq = _fusion_traffic(fops, "out_bytes_eq")
                        total.bytes_bf16eq += feq
                        if _is_attn(op.line) or any(
                                _is_attn(fo.line) for fo in fops[:40]):
                            total.attn_bytes_bf16eq += feq
        memo[key] = total
        return total

    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comp_cost(entry, False)


def analysis_record(text: str, pod_stride: int = 256) -> Dict:
    c = analyze(text, pod_stride)
    return {
        "flops": c.flops,
        "bytes_accessed": c.bytes,
        "bytes_accessed_bf16eq": c.bytes_bf16eq,
        "attn_bytes_bf16eq": c.attn_bytes_bf16eq,
        "collectives": c.coll,
        "collective_operand_bytes": sum(
            v["operand_bytes"] for v in c.coll.values()),
        "collective_link_bytes": sum(
            v["link_bytes"] for v in c.coll.values()),
        "collective_link_bytes_bf16eq": sum(
            v["link_bytes_bf16eq"] for v in c.coll.values()),
        "cross_pod_link_bytes": sum(
            v["cross_pod_link_bytes"] for v in c.coll.values()),
    }
