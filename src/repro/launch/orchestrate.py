"""Supervised coded training — the orchestrator CLI.

Runs a :class:`~repro.api.CodedSession` under the control plane of
:mod:`repro.orchestrator`: a pool of real worker processes, heartbeat
liveness, seeded failure injection, and event-driven replanning that
closes the paper's fit-replan loop from MEASURED runtimes
(``CodedCluster.from_observations``).  The thin shell over
:class:`~repro.orchestrator.controller.Orchestrator` — all policy
lives in the library.

Examples::

    # a seeded kill + slow-edge episode, metrics to JSONL
    python -m repro.launch.orchestrate --smoke --steps 12 \
        --inject "kill:w0.1@3,slow:e1@5x2:4.0" \
        --metrics-out /tmp/orch.jsonl --expect-zero-recompile

    # random-but-reproducible soak
    python -m repro.launch.orchestrate --smoke --steps 20 \
        --inject seeded:4 --seed 7 --min-replans 1
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.api import CodedCluster, CodedSession, planner_for_scheme
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.orchestrator import (HeartbeatConfig, InjectionSchedule,
                                MetricsSink, Orchestrator,
                                OrchestratorConfig)


def _parse_schedule(spec: str, topo, steps: int, seed: int):
    """``--inject`` accepts the spec grammar or ``seeded[:n_events]``."""
    if not spec:
        return InjectionSchedule()
    if spec == "seeded" or spec.startswith("seeded:"):
        n = int(spec.split(":", 1)[1]) if ":" in spec else 3
        return InjectionSchedule.seeded(seed, topo, steps, n_events=n)
    return InjectionSchedule.parse(spec)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken config for CI-sized runs")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--part-batch", type=int, default=1)
    ap.add_argument("--scheme", default="hgc",
                    help="planner scheme (see docs/planners.md)")
    ap.add_argument("--planner", default="",
                    help="override planner by name (jncss | fixed | "
                         "uniform | grouped | comm_budget); empty: "
                         "derive from --scheme")
    ap.add_argument("--s-e", type=int, default=1)
    ap.add_argument("--s-w", type=int, default=1)
    ap.add_argument("--n-edges", type=int, default=3)
    ap.add_argument("--n-workers", type=int, default=3)
    ap.add_argument("--cluster", default="hetero",
                    choices=["homogeneous", "hetero"])
    ap.add_argument("--dist", default="off",
                    choices=["off", "coded", "coded_int8", "coded_q"],
                    help="aggregation mode of the underlying session "
                         "(coded_q: int8 codec default)")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    # ---- control plane ------------------------------------------------
    ap.add_argument("--inject", default="",
                    help="failure schedule: 'kill:w0.1@3,slow:e1@5x2:4' "
                         "(kind:target@step[xduration][:factor]) or "
                         "'seeded[:n_events]' for a reproducible "
                         "random schedule")
    ap.add_argument("--heartbeat-ms", type=float, default=0.0,
                    help="heartbeat interval on the virtual clock "
                         "(0: derive from the plan's expected "
                         "iteration time)")
    ap.add_argument("--heartbeat-timeout-ms", type=float, default=0.0,
                    help="miss deadline (0: 2.5x the interval)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "process", "thread"],
                    help="worker pool backend (auto: processes when "
                         "the runner has >= 2 cores)")
    ap.add_argument("--replan-cooldown", type=int, default=2)
    ap.add_argument("--metrics-out", default="",
                    help="per-iteration metrics JSONL path")
    ap.add_argument("--expect-zero-recompile", action="store_true",
                    help="exit 1 unless the episode ends with exactly "
                         "one compiled train executable")
    ap.add_argument("--min-replans", type=int, default=0,
                    help="exit 1 unless at least this many successful "
                         "replans happened")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    ctor = (CodedCluster.hetero if args.cluster == "hetero"
            else CodedCluster.homogeneous)
    planner = (args.planner if args.planner
               else planner_for_scheme(args.scheme, args.s_e, args.s_w))
    try:
        session = CodedSession(
            ctor(args.n_edges, args.n_workers), cfg,
            planner=planner, mode=args.dist, seq_len=args.seq_len,
            part_batch=args.part_batch, lr=args.lr,
            total_steps=args.steps, seed=args.seed,
            verbose=args.verbose,
        )
    except ValueError as e:
        raise SystemExit(f"[orchestrate] {e}")

    schedule = _parse_schedule(args.inject, session.cluster.topo,
                               args.steps, args.seed)
    hb = None
    if args.heartbeat_ms > 0:
        hb = HeartbeatConfig(
            interval_ms=args.heartbeat_ms,
            timeout_ms=(args.heartbeat_timeout_ms
                        or 2.5 * args.heartbeat_ms),
        )
    orch = Orchestrator(
        session,
        OrchestratorConfig(
            steps=args.steps, backend=args.backend, heartbeat=hb,
            replan_cooldown=args.replan_cooldown, verbose=args.verbose,
        ),
        schedule=schedule,
        metrics=MetricsSink(args.metrics_out or None),
    )
    summary = orch.run_episode()
    print(json.dumps(summary, indent=1))

    failed = False
    if args.expect_zero_recompile:
        entries = summary["jit_cache_entries"]
        if entries == -1:
            print("[orchestrate] WARNING: jit cache size unavailable "
                  "on this jax; zero-recompile check skipped",
                  file=sys.stderr)
        elif entries != 1:
            print(f"[orchestrate] FAIL: expected exactly 1 compiled "
                  f"train executable, found {entries}", file=sys.stderr)
            failed = True
    if summary["counters"]["replans"] < args.min_replans:
        print(f"[orchestrate] FAIL: expected >= {args.min_replans} "
              f"successful replans, got "
              f"{summary['counters']['replans']}", file=sys.stderr)
        failed = True
    if failed:
        sys.exit(1)
    return summary


if __name__ == "__main__":
    main()
