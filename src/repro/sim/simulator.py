"""Paper-evaluation simulator (§V): full training runs of every scheme
on the paper's heterogeneous cluster, with sampled per-iteration times.

Two modes:
  * ``simulate_times``    — iteration times only (Fig. 8, comm loads),
  * ``simulate_training`` — real model training (logistic regression /
    CNN on the synthetic MNIST/CIFAR-like data) where each iteration's
    gradient is the scheme's actual aggregate (exact for coded schemes,
    partial for Greedy) and wall-clock advances by the sampled runtime
    (Figs. 5/6, Table I).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime_model import ClusterParams
from repro.core.schemes import Scheme, make_scheme
from repro.core.topology import Topology
from repro.data.pipeline import mnist_like, cifar_like, split_K_parts
from repro.models import classic


@dataclasses.dataclass
class TrainingTrace:
    scheme: str
    iter_times_ms: np.ndarray  # (T,)
    losses: np.ndarray  # (T,)
    accuracies: np.ndarray  # (n_evals,)
    eval_times_h: np.ndarray  # cumulative hours at each eval
    eval_iters: np.ndarray

    @property
    def total_time_h(self) -> float:
        return float(self.iter_times_ms.sum() / 3.6e6)

    def time_to_accuracy(self, target: float) -> Optional[float]:
        hits = np.flatnonzero(self.accuracies >= target)
        return float(self.eval_times_h[hits[0]]) if len(hits) else None


def simulate_times(
    scheme: Scheme,
    params: ClusterParams,
    iters: int,
    seed: int = 0,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # grouped schemes carry per-worker loads — compute times then differ
    # per edge; uniform schemes fall back to the scalar D
    D = getattr(scheme, "load_array", scheme.load)
    out = np.empty(iters)
    for t in range(iters):
        sample = params.sample_iteration(rng, D)
        out[t] = scheme.iteration(sample).time
    return out


def _make_model(dataset: str, seed: int):
    rng = jax.random.PRNGKey(seed)
    if dataset == "mnist":
        p = classic.init_logreg(rng)
        return p, classic.apply_logreg
    p = classic.init_cnn(rng)
    return p, classic.apply_cnn


def simulate_training(
    scheme_name: str,
    params: ClusterParams,
    dataset: str = "mnist",
    non_iid_level: int = 1,
    K: int = 40,
    iters: int = 500,
    lr: float = 0.05,
    batch_per_part: int = 64,
    eval_every: int = 20,
    n_data: int = 8_000,
    n_eval: int = 1_000,
    seed: int = 0,
    s_e: int = 1,
    s_w: int = 1,
) -> TrainingTrace:
    """One full training run of one scheme (Figs. 5/6 & Table I)."""
    topo = params.topo
    scheme = make_scheme(
        scheme_name, topo, K, s_e=s_e, s_w=s_w, params=params, seed=seed
    )
    x, y = (mnist_like if dataset == "mnist" else cifar_like)(
        n_data + n_eval, seed=seed
    )
    x_eval, y_eval = x[n_data:], y[n_data:]
    parts = split_K_parts(
        x[:n_data], y[:n_data], K, non_iid_level, seed=seed
    )
    model_params, apply = _make_model(dataset, seed)
    flat, treedef = jax.tree.flatten(model_params)
    sizes = [int(np.prod(p.shape)) for p in flat]

    def to_vec(tree):
        return jnp.concatenate(
            [jnp.ravel(l) for l in jax.tree.leaves(tree)]
        )

    def from_vec(vec):
        leaves = []
        off = 0
        for p, s in zip(flat, sizes):
            leaves.append(vec[off : off + s].reshape(p.shape))
            off += s
        return jax.tree.unflatten(treedef, leaves)

    @jax.jit
    def part_grads(p, xs, ys):
        """Stacked per-part gradient matrix g_parts (K, dim)."""

        def one(xk, yk):
            return to_vec(classic.grad_fn(apply, p, xk, yk))

        return jax.vmap(one)(xs, ys)

    @jax.jit
    def eval_acc(p):
        return classic.accuracy(apply(p, x_eval), y_eval)

    # pre-stack part minibatches per iteration from each part
    rng = np.random.default_rng(seed + 1)
    px = np.stack([p[0] for p in parts])  # (K, n_k, ...)
    py = np.stack([p[1] for p in parts])
    n_per = px.shape[1]

    times = np.empty(iters)
    losses = np.empty(iters)
    accs: List[float] = []
    acc_times: List[float] = []
    acc_iters: List[int] = []
    cum_ms = 0.0
    D = getattr(scheme, "load_array", scheme.load)
    for t in range(iters):
        sample = params.sample_iteration(rng, D)
        outcome = scheme.iteration(sample)
        times[t] = outcome.time
        cum_ms += outcome.time
        sel = rng.integers(0, n_per, size=min(batch_per_part, n_per))
        g_parts = np.asarray(part_grads(
            model_params, jnp.asarray(px[:, sel]), jnp.asarray(py[:, sel])
        ))
        agg = scheme.gradient(g_parts, outcome) / max(len(parts), 1)
        model_params = from_vec(
            to_vec(model_params) - lr * jnp.asarray(agg)
        )
        losses[t] = float(np.linalg.norm(agg))
        if t % eval_every == 0 or t == iters - 1:
            accs.append(float(eval_acc(model_params)))
            acc_times.append(cum_ms / 3.6e6)
            acc_iters.append(t)
    return TrainingTrace(
        scheme=scheme_name,
        iter_times_ms=times,
        losses=losses,
        accuracies=np.asarray(accs),
        eval_times_h=np.asarray(acc_times),
        eval_iters=np.asarray(acc_iters),
    )
