"""Test meshes for host-device shard_map runs.

The production meshes live in ``repro.launch.mesh`` (256/512 chips);
this factory builds the small (pod × data × model) meshes used by the
multi-device CPU tests (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

HGC mapping: "pod" = edge layer, "data" = worker layer within an edge,
"model" = tensor-parallel shards of one worker group.
"""
from __future__ import annotations

import jax


def make_test_mesh(pods: int, data: int, model: int):
    """(pods × data × model) mesh with the canonical axis names."""
    need = pods * data * model
    have = len(jax.devices())
    if have < need:
        raise ValueError(
            f"mesh ({pods}×{data}×{model}) needs {need} devices, have "
            f"{have}; set XLA_FLAGS=--xla_force_host_platform_device_count={need}"
        )
    return jax.make_mesh((pods, data, model), ("pod", "data", "model"))


def make_serve_mesh(model: int, data: int = 1):
    """Serving mesh: tensor-parallel "model" axis (+ optional batch
    "data" axis), no pod layer — serving has no coded aggregation, but
    it partitions from the SAME pspec rules as training (canonical axis
    names, so ``dist.sharding`` applies unchanged)."""
    return make_test_mesh(1, data, model)
