"""Test meshes for host-device shard_map runs.

The production meshes live in ``repro.launch.mesh`` (256/512 chips);
this factory builds the small (pod × data × model) meshes used by the
multi-device CPU tests (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

HGC mapping: "pod" = edge layer, "data" = worker layer within an edge,
"model" = tensor-parallel shards of one worker group, "stage" = pipeline
stages (each stage replicates the coded (pod, data) farm for its own
contiguous layer block).
"""
from __future__ import annotations

import jax


def make_test_mesh(pods: int, data: int, model: int, stages: int = 1):
    """(stage × pods × data × model) mesh with the canonical axis names.

    ``stages == 1`` (the default) keeps the historic 3-axis
    (pod, data, model) mesh — no "stage" axis, so every pspec rule and
    shard_map spec that never mentions it is byte-identical to the
    pre-pipeline layout.  ``stages > 1`` prepends a leading "stage"
    axis: the full coded (pod, data, model) sub-mesh is replicated per
    pipeline stage and activations flow stage→stage via ppermute.
    """
    need = stages * pods * data * model
    have = len(jax.devices())
    if have < need:
        raise ValueError(
            f"mesh ({stages}×{pods}×{data}×{model}) needs {need} "
            f"devices, have "
            f"{have}; set XLA_FLAGS=--xla_force_host_platform_device_count={need}"
        )
    if stages <= 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh(
        (stages, pods, data, model), ("stage", "pod", "data", "model")
    )


def make_serve_mesh(model: int, data: int = 1):
    """Serving mesh: tensor-parallel "model" axis (+ optional batch
    "data" axis), no pod layer — serving has no coded aggregation, but
    it partitions from the SAME pspec rules as training (canonical axis
    names, so ``dist.sharding`` applies unchanged)."""
    return make_test_mesh(1, data, model)
