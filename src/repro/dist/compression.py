"""Blockwise int8 gradient compression for the edge→master hop.

The paper's runtime model (§IV-A) makes the edge↔master link the scarce
resource (τ_e up to 10× τ_w); quantizing the per-edge partial aggregate
``G_i`` (eq. 25) to int8 cuts that hop's bytes 4× while the in-pod
worker↔edge stage stays exact.  ``coded_combine_q``
(:mod:`repro.kernels.coded_combine`) consumes exactly this layout —
int8 payload + per-block f32 scales — and dequantizes in VMEM.

Error feedback (:func:`compress_error_feedback`) keeps the *time-
averaged* transmitted gradient unbiased, which is what SGD needs when
the same hop is compressed every iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

DEFAULT_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class QuantMeta:
    """Static shape info needed to undo :func:`quantize_int8`."""

    shape: Tuple[int, ...]
    block: int
    pad: int


def quantize_int8(x, block: int = DEFAULT_BLOCK):
    """Blockwise symmetric int8: returns ``(q, scales, meta)``.

    ``q`` is a flat int8 vector (zero-padded to a block multiple so it
    feeds ``coded_combine_q`` directly), ``scales`` one f32 per block
    (max-abs / 127).  Max elementwise error ≤ max|x| / 127 · (1/2 + ε).
    """
    x = jnp.asarray(x, jnp.float32)
    shape = tuple(x.shape)
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scales = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(
        jnp.round(blocks / safe[:, None]), -127, 127
    ).astype(jnp.int8)
    return q.reshape(-1), scales, QuantMeta(shape=shape, block=block, pad=pad)


def dequantize_int8(q, scales, meta: QuantMeta):
    """Inverse of :func:`quantize_int8` (up to rounding error)."""
    blocks = jnp.asarray(q).reshape(-1, meta.block).astype(jnp.float32)
    flat = (blocks * jnp.asarray(scales)[:, None]).reshape(-1)
    n = flat.size - meta.pad
    return flat[:n].reshape(meta.shape)


# ----------------------------------------------------------------------
# pytree wrappers
# ----------------------------------------------------------------------
def _is_qleaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scales", "meta"}


def quantize_tree(tree: PyTree, block: int = DEFAULT_BLOCK) -> PyTree:
    """Quantize every leaf; result mirrors the tree with q-leaf dicts."""

    def one(x):
        q, s, meta = quantize_int8(x, block=block)
        return {"q": q, "scales": s, "meta": meta}

    return jax.tree.map(one, tree)


def dequantize_tree(qtree: PyTree) -> PyTree:
    """Inverse of :func:`quantize_tree`."""
    return jax.tree.map(
        lambda d: dequantize_int8(d["q"], d["scales"], d["meta"]),
        qtree,
        is_leaf=_is_qleaf,
    )


def init_pod_residuals(tree: PyTree, n_pods: int) -> PyTree:
    """Zero EF residuals for the sharded train step, one row per pod.

    Leaves are ``(n_pods, *leaf.shape)`` f32 — sharded ``P("pod")`` they
    hand each pod its own residual inside the shard_map region (see
    :func:`repro.dist.grad_sync.compressed_coded_psum`).
    """
    return jax.tree.map(
        lambda x: jnp.zeros((n_pods,) + tuple(x.shape), jnp.float32), tree
    )


def compress_error_feedback(
    tree: PyTree, residual: PyTree, block: int = DEFAULT_BLOCK
) -> Tuple[PyTree, PyTree]:
    """One EF-SGD compression round: ``(q_tree, new_residual)``.

    Quantizes ``tree + residual``; the new residual is what the int8
    payload failed to carry, so transmitted values telescope — the sum
    of T dequantized sends equals ``T·tree`` up to one residual.
    """
    target = jax.tree.map(lambda g, r: g + r, tree, residual)
    qtree = quantize_tree(target, block=block)
    sent = dequantize_tree(qtree)
    new_residual = jax.tree.map(lambda t, s: t - s, target, sent)
    return qtree, new_residual
