"""Blockwise gradient compression for the edge→master hop.

The paper's runtime model (§IV-A) makes the edge↔master link the scarce
resource (τ_e up to 10× τ_w); quantizing the per-edge partial aggregate
``G_i`` (eq. 25) cuts that hop's bytes while the in-pod worker↔edge
stage stays exact.  Three codecs share one contract — flat payload
padded to a block multiple, one f32 scale per block, exact-zero pad
region — so the fused Pallas dequant-combine kernels
(:mod:`repro.kernels.coded_combine`) consume any of them:

  ========  ======================  ==================  ==============
  mode      payload                 bytes per value     scale formula
  ========  ======================  ==================  ==============
  int8      int8, one per value     1                   max|x| / 127
  int4      two nibbles per int8    0.5 (packed)        max|x| / 7
  fp8       float8_e4m3fn           1                   max|x| / 448
  ========  ======================  ==================  ==============

Error feedback (:func:`compress_error_feedback`) keeps the *time-
averaged* transmitted gradient unbiased for every codec, which is what
SGD needs when the same hop is compressed every iteration.

Pad invariant: the flat vector is zero-padded up to a block multiple,
and the pad positions are masked OUT of the per-block scale reduction —
pad values can never influence a block's scale, and they quantize to
exactly 0 in every codec (asserted by tests/test_kernels.py), so the
kernel-side combine over the padded tail contributes nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

DEFAULT_BLOCK = 256

#: symmetric quantization range per codec (max representable magnitude)
_QMAX = {"int8": 127.0, "int4": 7.0, "fp8": 448.0}

COMPRESSION_MODES = tuple(_QMAX)


def fp8_dtype():
    """The fp8-e4m3 payload dtype (clear error on ancient jax)."""
    dt = getattr(jnp, "float8_e4m3fn", None)
    if dt is None:  # pragma: no cover - all CI jax versions have it
        raise RuntimeError(
            "grad_compression='fp8' needs jnp.float8_e4m3fn "
            "(jax >= 0.4.21)"
        )
    return dt


@dataclasses.dataclass(frozen=True)
class QuantMeta:
    """Static shape info needed to undo a blockwise quantizer."""

    shape: Tuple[int, ...]
    block: int
    pad: int
    mode: str = "int8"


def _blocked(x, block: int):
    """Flatten + zero-pad to a block multiple; per-block scales with the
    pad positions masked out of the max reduction (the pad invariant)."""
    x = jnp.asarray(x, jnp.float32)
    shape = tuple(x.shape)
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    n = flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    mags = jnp.abs(blocks)
    if pad:
        valid = (jnp.arange(flat.size) < n).reshape(-1, block)
        mags = jnp.where(valid, mags, 0.0)
    amax = jnp.max(mags, axis=1)
    return blocks, amax, shape, pad


def quantize_int8(x, block: int = DEFAULT_BLOCK):
    """Blockwise symmetric int8: returns ``(q, scales, meta)``.

    ``q`` is a flat int8 vector (zero-padded to a block multiple so it
    feeds ``coded_combine_q`` directly), ``scales`` one f32 per block
    (max-abs / 127).  Max elementwise error ≤ max|x| / 127 · (1/2 + ε).
    """
    blocks, amax, shape, pad = _blocked(x, block)
    scales = amax / 127.0
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(
        jnp.round(blocks / safe[:, None]), -127, 127
    ).astype(jnp.int8)
    return q.reshape(-1), scales, QuantMeta(
        shape=shape, block=block, pad=pad, mode="int8")


def dequantize_int8(q, scales, meta: QuantMeta):
    """Inverse of :func:`quantize_int8` (up to rounding error)."""
    blocks = jnp.asarray(q).reshape(-1, meta.block).astype(jnp.float32)
    flat = (blocks * jnp.asarray(scales)[:, None]).reshape(-1)
    n = flat.size - meta.pad
    return flat[:n].reshape(meta.shape)


# ----------------------------------------------------------------------
# int4: two nibbles per int8 byte
# ----------------------------------------------------------------------
def pack_int4(vals: jnp.ndarray) -> jnp.ndarray:
    """Pack an even-length int vector in [-8, 7] into nibbles.

    Element 2i rides the LOW nibble of byte i, element 2i+1 the HIGH
    nibble (the layout ``coded_combine_q4`` unpacks in VMEM).
    """
    v = jnp.asarray(vals, jnp.int32) & 0xF
    lo = v[0::2]
    hi = v[1::2]
    return (lo | (hi << 4)).astype(jnp.uint8).view(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4` → int32 values in [-8, 7]."""
    p = jnp.asarray(packed).view(jnp.uint8).astype(jnp.int32)
    lo = ((p & 0xF) ^ 8) - 8          # sign-extend the low nibble
    hi = (((p >> 4) & 0xF) ^ 8) - 8   # sign-extend the high nibble
    return jnp.stack([lo, hi], axis=-1).reshape(-1)


def quantize_int4(x, block: int = DEFAULT_BLOCK):
    """Blockwise symmetric packed int4: ``(q_packed, scales, meta)``.

    ``q_packed`` is int8 of HALF the padded length — two values per
    byte — for a 8× byte cut vs f32 on the wire.  Values are clipped to
    [-7, 7] (scale = max-abs / 7) so the code stays symmetric.  ``block``
    must be even (nibble pairs never straddle a scale block).
    """
    if block % 2:
        raise ValueError(f"int4 needs an even block, got {block}")
    blocks, amax, shape, pad = _blocked(x, block)
    scales = amax / 7.0
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -7, 7).astype(
        jnp.int32)
    packed = pack_int4(q.reshape(-1))
    return packed, scales, QuantMeta(
        shape=shape, block=block, pad=pad, mode="int4")


def dequantize_int4(q_packed, scales, meta: QuantMeta):
    """Inverse of :func:`quantize_int4` (up to rounding error)."""
    vals = unpack_int4(q_packed).astype(jnp.float32)
    blocks = vals.reshape(-1, meta.block)
    flat = (blocks * jnp.asarray(scales)[:, None]).reshape(-1)
    n = flat.size - meta.pad
    return flat[:n].reshape(meta.shape)


# ----------------------------------------------------------------------
# fp8 (e4m3): blockwise-scaled float payload
# ----------------------------------------------------------------------
def quantize_fp8(x, block: int = DEFAULT_BLOCK):
    """Blockwise-scaled fp8-e4m3: ``(q_f8, scales, meta)``.

    The block scale maps max|x| onto the e4m3 max normal (448), so the
    payload spends its exponent range on the block's dynamic range —
    relative error ~2^-3 per value vs int8's fixed 1/127 absolute grid.
    """
    dt = fp8_dtype()
    blocks, amax, shape, pad = _blocked(x, block)
    scales = amax / 448.0
    safe = jnp.where(scales > 0, scales, 1.0)
    q = (blocks / safe[:, None]).astype(dt)
    return q.reshape(-1), scales, QuantMeta(
        shape=shape, block=block, pad=pad, mode="fp8")


def dequantize_fp8(q, scales, meta: QuantMeta):
    """Inverse of :func:`quantize_fp8` (up to e4m3 rounding error)."""
    blocks = jnp.asarray(q).astype(jnp.float32).reshape(-1, meta.block)
    flat = (blocks * jnp.asarray(scales)[:, None]).reshape(-1)
    n = flat.size - meta.pad
    return flat[:n].reshape(meta.shape)


# ----------------------------------------------------------------------
# mode dispatch (the one seam grad_sync / trees go through)
# ----------------------------------------------------------------------
_QUANTIZE = {
    "int8": quantize_int8,
    "int4": quantize_int4,
    "fp8": quantize_fp8,
}
_DEQUANTIZE = {
    "int8": dequantize_int8,
    "int4": dequantize_int4,
    "fp8": dequantize_fp8,
}


def quantize(x, block: int = DEFAULT_BLOCK, mode: str = "int8"):
    """Blockwise quantize under any codec: ``(payload, scales, meta)``."""
    try:
        return _QUANTIZE[mode](x, block=block)
    except KeyError:
        raise ValueError(
            f"unknown compression mode {mode!r} "
            f"(choose from {COMPRESSION_MODES})"
        ) from None


def dequantize(q, scales, meta: QuantMeta):
    """Inverse of :func:`quantize` — the codec rides ``meta.mode``."""
    return _DEQUANTIZE[meta.mode](q, scales, meta)


def wire_bytes_per_value(mode: str, block: int = DEFAULT_BLOCK) -> float:
    """Cross-pod bytes per gradient value (payload + amortized scales)."""
    payload = {"int8": 1.0, "int4": 0.5, "fp8": 1.0}[mode]
    return payload + 4.0 / block


# ----------------------------------------------------------------------
# pytree wrappers
# ----------------------------------------------------------------------
def _is_qleaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scales", "meta"}


def quantize_tree(tree: PyTree, block: int = DEFAULT_BLOCK,
                  mode: str = "int8") -> PyTree:
    """Quantize every leaf; result mirrors the tree with q-leaf dicts."""

    def one(x):
        q, s, meta = quantize(x, block=block, mode=mode)
        return {"q": q, "scales": s, "meta": meta}

    return jax.tree.map(one, tree)


def dequantize_tree(qtree: PyTree) -> PyTree:
    """Inverse of :func:`quantize_tree`."""
    return jax.tree.map(
        lambda d: dequantize(d["q"], d["scales"], d["meta"]),
        qtree,
        is_leaf=_is_qleaf,
    )


def init_pod_residuals(tree: PyTree, n_pods: int) -> PyTree:
    """Zero EF residuals for the sharded train step, one row per pod.

    Leaves are ``(n_pods, *leaf.shape)`` f32 — sharded ``P("pod")`` they
    hand each pod its own residual inside the shard_map region (see
    :func:`repro.dist.grad_sync.compressed_coded_psum`).  The layout is
    codec-independent: int8/int4/fp8 all carry f32 residuals, so a
    checkpointed residual restores under any ``grad_compression``.
    """
    return jax.tree.map(
        lambda x: jnp.zeros((n_pods,) + tuple(x.shape), jnp.float32), tree
    )


def compress_error_feedback(
    tree: PyTree, residual: PyTree, block: int = DEFAULT_BLOCK,
    mode: str = "int8",
) -> Tuple[PyTree, PyTree]:
    """One EF-SGD compression round: ``(q_tree, new_residual)``.

    Quantizes ``tree + residual``; the new residual is what the
    low-precision payload failed to carry, so transmitted values
    telescope — the sum of T dequantized sends equals ``T·tree`` up to
    one residual.  The telescoping identity holds for every codec
    because the residual is always computed against the local dequant
    of the exact payload the wire carries.
    """
    target = jax.tree.map(lambda g, r: g + r, tree, residual)
    qtree = quantize_tree(target, block=block, mode=mode)
    sent = dequantize_tree(qtree)
    new_residual = jax.tree.map(lambda t, s: t - s, target, sent)
    return qtree, new_residual
