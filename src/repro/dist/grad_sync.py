"""Two-stage coded gradient aggregation as shard_map collectives.

The distributed form of the paper's decode pipeline on a
(pod × data × model) mesh, where pod=edge and data=worker:

  worker encode (eq. 22)  G_ij = Σ_k d^i_jk b_ik g_k   — the weighted
      loss of ``launch.steps`` already yields G_ij as the local gradient;
  edge decode (eq. 25)    G_i  = Σ_{j∈F_i} c^i_j G_ij  — ``psum`` over
      the "data" axis;
  master decode (eq. 27)  g    = Σ_{i∈F} a_i G_i       — ``psum`` over
      the "pod" axis.

Because λ_ij = a_i·c^i_j enters as a *runtime scalar operand*
(:func:`lam_array_from_code`), a straggler drop changes only an input
array — the compiled step is reused, zero recompilation (the headline
elasticity claim).  The bandwidth-limited edge→master hop optionally
rides :mod:`repro.dist.compression`; host-side bulk encode/decode rides
the Pallas ``coded_combine`` kernel via :mod:`repro.kernels.ops`.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist._compat import on_tpu, shard_map

from repro.dist import compression
from repro.kernels import ops as kernel_ops

PyTree = Any

WORKER_AXIS = "data"  # within-edge aggregation axis (eq. 25)
EDGE_AXIS = "pod"     # cross-edge aggregation axis (eq. 27)


# ----------------------------------------------------------------------
# λ weights: the dist ↔ core seam
# ----------------------------------------------------------------------
def lam_array_from_code(
    code,
    fast_edges: Sequence[int],
    fast_workers: Sequence[Sequence[int]],
    pods: int,
    data: int,
    dtype=np.float32,
) -> np.ndarray:
    """Collapsed per-worker decode weights λ_ij as a (pods, data) array.

    Row i is edge/pod i, column j worker/data-group j; equals
    ``HGCCode.collapsed_weights`` reshaped onto the mesh (stragglers 0).
    """
    if (code.topo.n, code.topo.m) != (pods, (data,) * pods):
        raise ValueError(
            f"code topology {code.topo.m} does not match the "
            f"({pods}×{data}) mesh"
        )
    lam = code.collapsed_weights(fast_edges, fast_workers)
    return np.asarray(lam, dtype).reshape(pods, data)


# ----------------------------------------------------------------------
# in-shard_map collective (call from inside a shard_map region)
# ----------------------------------------------------------------------
def coded_weighted_psum(
    tree: PyTree,
    lam,
    axes: Tuple[str, str] = (EDGE_AXIS, WORKER_AXIS),
) -> PyTree:
    """λ-weighted hierarchical psum of this shard group's gradient.

    ``lam`` is THIS group's scalar λ_ij.  Stage 1 sums λ-weighted
    messages over the worker axis (edge decode, eq. 25); stage 2 sums
    the per-edge partials over the pod axis (master decode, eq. 27).
    Stragglers participate with λ=0 — shapes never change.
    """
    pod_axis, worker_axis = axes
    lam = jnp.asarray(lam)

    def one(x):
        y = x * lam.astype(x.dtype)
        y = lax.psum(y, worker_axis)  # workers → edge   (eq. 25)
        y = lax.psum(y, pod_axis)     # edges   → master (eq. 27)
        return y

    return jax.tree.map(one, tree)


def compressed_coded_psum(
    tree: PyTree,
    lam,
    residual: PyTree,
    *,
    n_pods: int,
    axes: Tuple[str, str] = (EDGE_AXIS, WORKER_AXIS),
    block: int = 64,
    mode: str = "int8",
    use_pallas=None,
) -> Tuple[PyTree, PyTree]:
    """λ-weighted decode with a quantized + error-feedback cross-pod hop.

    In-shard_map counterpart of :func:`coded_weighted_psum` for the
    bandwidth-limited regime: stage 1 (worker→edge, eq. 25) stays an
    exact psum; the per-edge partial plus this pod's EF residual is then
    blockwise quantized (``mode`` ∈ int8 | int4 | fp8, see
    :mod:`repro.dist.compression`), all-gathered across the pod axis
    and combined through the matching fused dequant kernel (eq. 27 over
    quantized payloads — 4× fewer cross-pod bytes for int8/fp8, 8× for
    packed int4).  ``residual`` leaves carry a leading per-pod axis
    (local block size 1 inside shard_map) and stay f32 for every codec,
    so checkpoints restore under any ``mode``; the returned residual is
    what the low-precision payload failed to carry, so transmitted
    values telescope (EF-SGD — time-averaged gradient stays unbiased).

    Returns ``(decoded_tree, new_residual)``.
    """
    pod_axis, worker_axis = axes
    if use_pallas is None:
        use_pallas = on_tpu()
    lam = jnp.asarray(lam)

    def leaf(x, r):
        y = x * lam.astype(jnp.float32)
        y = lax.psum(y, worker_axis)  # exact edge decode (eq. 25)
        target = y + r.reshape(y.shape).astype(jnp.float32)
        q, scales, meta = compression.quantize(target, block=block,
                                               mode=mode)
        # local dequant: the EF update needs what the wire will carry
        sent = compression.dequantize(q, scales, meta)
        new_r = (target - sent).reshape(r.shape).astype(r.dtype)
        qs = lax.all_gather(q, pod_axis)       # (n_pods, payload)
        ss = lax.all_gather(scales, pod_axis)  # (n_pods, nb)
        ones = jnp.ones((1, n_pods), jnp.float32)
        out = kernel_ops.combine_compressed(
            mode, ones, qs, ss, block=block, use_pallas=use_pallas
        )[0]
        return out[: y.size].reshape(y.shape).astype(x.dtype), new_r

    flat_x, treedef = jax.tree.flatten(tree)
    flat_r = jax.tree.leaves(residual)
    if len(flat_x) != len(flat_r):
        raise ValueError(
            f"residual has {len(flat_r)} leaves, gradients {len(flat_x)}"
        )
    outs = [leaf(x, r) for x, r in zip(flat_x, flat_r)]
    decoded = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_residual = jax.tree.unflatten(
        jax.tree.structure(residual), [o[1] for o in outs]
    )
    return decoded, new_residual


# ----------------------------------------------------------------------
# mesh-level builders (wrap shard_map; jit-compatible)
# ----------------------------------------------------------------------
def make_coded_allreduce(mesh, axes: Tuple[str, str] = (EDGE_AXIS, WORKER_AXIS)):
    """``runner(tree, lam)``: the two-stage decode as a mesh program.

    ``lam``: (pods, data) array of λ_ij (zeros drop stragglers).  The
    tree is a REPLICATED value standing in for every group's local
    contribution — shard_map hands each (pod, data) group the same
    leaves, weights them by that group's λ_ij and runs the two psum
    stages, so the result is Σ_ij λ_ij · tree (used to validate the
    hierarchical reduction against a flat sum).  For *distinct*
    per-group gradients, call :func:`coded_weighted_psum` from inside
    the train step's own shard_map region, where each group's gradient
    is already device-local (see tests/test_dist_core_seam.py).
    """
    pod_axis, worker_axis = axes

    def inner(tree, lam_block):
        return coded_weighted_psum(tree, lam_block.reshape(()), axes)

    fn = shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(), P(pod_axis, worker_axis)),
        out_specs=P(),
        check_rep=False,
    )

    def runner(tree: PyTree, lam) -> PyTree:
        return fn(tree, jnp.asarray(lam, jnp.float32))

    return runner


def make_compressed_cross_pod_sum(
    mesh,
    axes: Tuple[str, str] = (EDGE_AXIS, WORKER_AXIS),
    block: int = 64,
    mode: str = "int8",
):
    """Coded all-reduce with a quantized edge→master hop.

    Stage 1 (worker→edge, in-pod links) stays exact; the per-edge
    partial is then blockwise quantized before crossing the pod
    boundary — the bytes that actually traverse the scarce edge↔master
    link shrink 4× (int8/fp8) or 8× (packed int4).  All pods' payloads
    + scales are gathered and combined with unit coefficients through
    the matching fused dequant-matmul Pallas kernel
    (``coded_combine_q`` / ``_q4`` / ``_f8``), mirroring the TPU hot
    path.
    """
    pod_axis, worker_axis = axes
    n_pods = mesh.shape[pod_axis]
    use_pallas = on_tpu()

    def inner(tree, lam_block):
        lam = lam_block.reshape(())

        def leaf(x):
            y = x * lam.astype(jnp.float32)
            y = lax.psum(y, worker_axis)  # exact edge decode (eq. 25)
            q, scales, _ = compression.quantize(y, block=block,
                                                mode=mode)
            # gather every edge's partial payload + scales at the master
            qs = lax.all_gather(q, pod_axis)       # (n, payload)
            ss = lax.all_gather(scales, pod_axis)  # (n, nb)
            ones = jnp.ones((1, n_pods), jnp.float32)
            out = kernel_ops.combine_compressed(
                mode, ones, qs, ss, block=block, use_pallas=use_pallas
            )[0]
            return out[: y.size].reshape(y.shape)

        return jax.tree.map(leaf, tree)

    fn = shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(), P(pod_axis, worker_axis)),
        out_specs=P(),
        check_rep=False,
    )

    def runner(tree: PyTree, lam) -> PyTree:
        return fn(tree, jnp.asarray(lam, jnp.float32))

    return runner


# ----------------------------------------------------------------------
# host-side bulk encode/decode (Pallas coded_combine hot path)
# ----------------------------------------------------------------------
def encode_messages(code, g_parts) -> jnp.ndarray:
    """All workers' encoded messages (Σm_i, F) in one kernel launch."""
    return kernel_ops.encode_messages(code, g_parts)


def decode_gradient(code, messages, fast_edges, fast_workers) -> jnp.ndarray:
    """Decoded full gradient from worker messages via the λ weights."""
    return kernel_ops.decode_gradient(code, messages, fast_edges, fast_workers)
