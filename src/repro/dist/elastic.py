"""Elastic tolerance/topology replanning + straggler detection.

Mid-run adaptation in three moves (consumed by ``launch.train``):

  * :class:`StragglerDetector` — EWMA of observed per-worker iteration
    totals (eq. 31 samples); persistent drift is folded back into the
    cluster model's deterministic compute term ``c``,
  * :func:`replan` — re-run JNCSS (Algorithm 2) on the updated model and
    rebuild the HGC code for the chosen tolerance.  A tolerance change
    costs one host-side code rebuild; the compiled train step is reused
    because λ enters as data (see :mod:`repro.dist.grad_sync`),
  * :func:`shrink_topology` — drop PERMANENTLY failed edges/workers from
    the cluster description (transient stragglers need no action: the
    code tolerates them by construction).

The heterogeneity-aware replanning direction follows Wang et al.
(arXiv:1901.09339); HGC's two-layer structure makes it a pure
(s_e, s_w) grid search (paper Theorem 2).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.core import jncss as jncss_mod
from repro.core import tradeoff
from repro.core.hgc import HGCCode
from repro.core.runtime_model import ClusterParams, kth_min
from repro.core.topology import Tolerance, Topology


@dataclasses.dataclass(frozen=True)
class Plan:
    """A planning outcome: the deployed code + the planner diagnostics.

    Produced by :func:`replan` (JNCSS) or by any ``repro.api.Planner``
    strategy; ``jncss`` is ``None`` for fixed/uniform strategies.  The
    plan is also the λ provider of the deployed code: :meth:`lam` /
    :meth:`lam_array` turn an observed straggler pattern into the
    runtime decode-weight operand the train step consumes.
    """

    code: HGCCode
    tol: Tolerance
    K: int
    expected_iteration_ms: float
    jncss: Optional[jncss_mod.JNCSSResult] = None

    @property
    def load(self) -> int:
        return self.code.load

    @property
    def deployed(self) -> dict:
        """The (tolerance, K) triple checkpoints persist."""
        return {"s_e": self.tol.s_e, "s_w": self.tol.s_w, "K": self.K}

    def lam(self, fast_edges, fast_workers) -> np.ndarray:
        """Collapsed flat per-worker decode weights λ_ij (stragglers 0)."""
        return self.code.collapsed_weights(fast_edges, fast_workers)

    def lam_array(self, fast_edges, fast_workers) -> np.ndarray:
        """λ_ij as the (pods, data) runtime operand of the dist step.

        Requires a uniform topology (every edge the same worker count) —
        exactly the shape the (pod, data) mesh can carry.
        """
        topo = self.code.topo
        if len(set(topo.m)) != 1:
            raise ValueError(
                f"lam_array needs a uniform topology, got m={topo.m}"
            )
        # the one implementation of the λ→mesh mapping (jax-importing
        # module, hence lazy — this module stays numpy-only)
        from repro.dist.grad_sync import lam_array_from_code

        return lam_array_from_code(
            self.code, fast_edges, fast_workers, topo.n, topo.m[0]
        )


def price_tolerance(
    params: ClusterParams, tol: Tolerance, load: float
) -> float:
    """Expected iteration time T̂ (ms) of a tolerance at a deployed load.

    The JNCSS order-statistic expression (eq. 43 flavor) evaluated at
    the load ``D`` the built code actually carries — shared by
    :func:`replan` and the fixed-tolerance planner strategies so every
    ``Plan`` prices consistently.
    """
    scores, _ = jncss_mod._edge_scores(params, float(load), tol.s_w)
    return float(kth_min(scores, params.topo.n - tol.s_e))


def replan(
    params: ClusterParams,
    K: int,
    seed: int = 0,
    construction: str = "random",
    reuse: Optional[HGCCode] = None,
) -> Plan:
    """JNCSS-plan a tolerance for this cluster and build its HGC code.

    ``K`` is a target part count; it is bumped to the nearest
    construction-compatible value for the chosen (s_e, s_w) (divisibility
    of eqs. 15/18), so the returned ``plan.K`` may exceed the request.

    ``reuse``: the currently deployed code — when JNCSS lands on the
    same (tolerance, K, topology) the deployed code is returned as-is
    instead of being rebuilt, so part assignments (and therefore the
    caller's per-part data streams) stay valid with zero churn.
    """
    res = jncss_mod.solve(params, K)
    tol = Tolerance(res.s_e, res.s_w)
    K_c = tradeoff.compatible_K(params.topo, tol, at_least=K)
    if (
        reuse is not None
        and reuse.tol == tol
        and reuse.K == K_c
        and reuse.topo == params.topo
    ):
        code = reuse
    else:
        code = HGCCode.build(
            params.topo, tol, K=K_c, seed=seed, construction=construction
        )
    # res.T_tol was evaluated at the REQUESTED K's load; re-price the
    # order-statistic expression at the load the built code actually
    # carries (K_c ≥ K bumps D proportionally).
    T_deployed = price_tolerance(params, tol, code.load)
    return Plan(
        code=code,
        tol=tol,
        K=K_c,
        expected_iteration_ms=T_deployed,
        jncss=res,
    )


def shrink_topology(
    params: ClusterParams,
    dead_edges: Iterable[int] = (),
    dead_workers: Iterable[Tuple[int, int]] = (),
) -> ClusterParams:
    """Cluster model with permanently failed nodes removed.

    ``dead_workers`` are (edge, worker) pairs in the ORIGINAL indexing;
    workers under a dead edge are removed implicitly.  Model/optimizer
    state is topology-independent, so training resumes from the last
    checkpoint against the shrunk cluster.
    """
    dead_e = set(dead_edges)
    dead_w = set(tuple(p) for p in dead_workers)
    topo = params.topo
    keep_edges = [i for i in range(topo.n) if i not in dead_e]
    if not keep_edges:
        raise ValueError("all edges dead — nothing to shrink to")
    new_m = []
    keep_flat = []
    for i in keep_edges:
        kept = [j for j in range(topo.m[i]) if (i, j) not in dead_w]
        if not kept:
            raise ValueError(f"edge {i} has no surviving workers")
        new_m.append(len(kept))
        keep_flat.extend(topo.flat_index(i, j) for j in kept)
    idx = np.asarray(keep_flat, np.intp)
    eidx = np.asarray(keep_edges, np.intp)
    return ClusterParams(
        topo=Topology(m=tuple(new_m)),
        c=params.c[idx],
        gamma=params.gamma[idx],
        tau_w=params.tau_w[idx],
        p_w=params.p_w[idx],
        tau_e=params.tau_e[eidx],
        p_e=params.p_e[eidx],
        master_contention=params.master_contention,
    )


class StragglerDetector:
    """EWMA tracker of observed worker totals vs the cluster model.

    ``observe`` feeds one iteration's flat worker totals (eq. 31
    samples, as produced by ``ClusterParams.sample_iteration``);
    ``updated_params`` folds any persistent positive drift into the
    deterministic compute term ``c`` so the next JNCSS pass plans
    around nodes that *got* slow, not just nodes that *were* slow.
    """

    def __init__(self, params: ClusterParams, alpha: float = 0.3):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.params = params
        self.alpha = float(alpha)
        self.ewma: Optional[np.ndarray] = None
        self.n_obs = 0

    def observe(self, worker_total: Sequence[float]) -> None:
        wt = np.asarray(worker_total, np.float64)
        if wt.shape != (self.params.topo.total_workers,):
            raise ValueError(
                f"expected ({self.params.topo.total_workers},) totals, "
                f"got {wt.shape}"
            )
        if self.ewma is None:
            self.ewma = wt.copy()
        else:
            self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * wt
        self.n_obs += 1

    def drift(self, D_ref: float) -> np.ndarray:
        """Observed-minus-expected per-worker total (0 before data)."""
        if self.ewma is None:
            return np.zeros(self.params.topo.total_workers)
        return self.ewma - self.params.expected_worker_total(D_ref)

    def persistent_stragglers(
        self, D_ref: float, factor: float = 2.0
    ) -> np.ndarray:
        """Flat indices whose EWMA exceeds ``factor ×`` the model mean."""
        if self.ewma is None:
            return np.empty(0, np.intp)
        base = self.params.expected_worker_total(D_ref)
        return np.flatnonzero(self.ewma > factor * base)

    def state_dict(self) -> dict:
        """JSON-serializable snapshot (checkpoint ``extra`` payload).

        A restored run replans from *observed* delays instead of priors;
        floats survive the JSON round trip exactly (repr round-trip), so
        kill/resume replans bit-for-bit.
        """
        return {
            "alpha": self.alpha,
            "n_obs": self.n_obs,
            "ewma": None if self.ewma is None else self.ewma.tolist(),
        }

    def load_state_dict(self, d: dict) -> None:
        self.alpha = float(d["alpha"])
        self.n_obs = int(d["n_obs"])
        ewma = d.get("ewma")
        self.ewma = (
            None if ewma is None else np.asarray(ewma, np.float64).copy()
        )

    def updated_params(self, D_ref: float) -> ClusterParams:
        """Cluster model with positive drift folded into ``c``.

        Only slowdowns are applied (speedups are usually measurement
        luck); drift divides by ``D_ref`` because ``c`` is per-part.
        """
        extra = np.maximum(self.drift(D_ref), 0.0) / max(D_ref, 1e-12)
        return dataclasses.replace(self.params, c=self.params.c + extra)
