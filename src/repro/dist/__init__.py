"""``repro.dist`` — the JAX execution layer of the HGC reproduction.

Bridges the numpy code-construction world (``repro.core``) to sharded
JAX execution (``repro.launch`` / ``repro.models``):

  * :mod:`repro.dist.sharding`    — PartitionSpec rules + activation anchors,
  * :mod:`repro.dist.mesh`        — host-device test meshes (pod/data/model),
  * :mod:`repro.dist.grad_sync`   — the two-stage coded aggregation
    (paper eqs. 25/27) as shard_map collectives over the pod/data axes,
  * :mod:`repro.dist.compression` — blockwise int8 for the bandwidth-
    limited edge→master hop (+ error feedback),
  * :mod:`repro.dist.elastic`     — straggler detection and mid-run
    tolerance/topology replanning (JNCSS, Algorithm 2).

Layering: core → kernels → dist → launch/models → examples.  Submodules
import lazily at their own use sites; importing ``repro.dist`` itself
never touches jax device state.
"""
from repro.dist import compression, elastic  # numpy/jnp-light modules

__all__ = [
    "compression",
    "elastic",
    "grad_sync",
    "mesh",
    "sharding",
]


def __getattr__(name):
    # sharding/mesh/grad_sync pull in jax.sharding machinery — load on
    # first attribute access so `import repro.dist` stays cheap.
    if name in ("sharding", "mesh", "grad_sync"):
        import importlib

        return importlib.import_module(f"repro.dist.{name}")
    raise AttributeError(f"module 'repro.dist' has no attribute {name!r}")
