"""Sharding rules: PartitionSpec trees + activation anchors.

One source of truth for how every tensor of the system is laid out on
the (pod, data, model) production meshes of ``launch.mesh``:

  * ``params_pspecs``    — Megatron-style 2-D sharding (TP over "model",
    FSDP over "data") or pure data-parallel (``mode="dp_only"``),
  * ``opt_state_pspecs`` — optimizer moments follow their parameters
    (incl. adafactor's factored row/col accumulators),
  * ``batch_pspecs`` / ``cache_pspecs`` — input and decode-cache layouts,
  * ``fit_pspecs``       — clamps any rule to pjit's divisibility
    requirement (a non-dividing axis entry is dropped, never errors),
  * anchors (``anchor_activations`` …) — ``with_sharding_constraint``
    hooks the model code calls unconditionally; they are no-ops unless a
    surrounding :func:`activation_sharding` context is active.

In the HGC mapping (DESIGN.md §3) "pod" is the edge layer and "data"
the worker layer: parameters are never sharded across pods, so the only
cross-pod traffic is the coded gradient exchange of
:mod:`repro.dist.grad_sync`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# mesh axis roles
POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"
STAGE_AXIS = "stage"

_IS_SPEC = lambda x: isinstance(x, P)  # noqa: E731


# ----------------------------------------------------------------------
# ShardCtx — the execution seam between launch.steps and models/
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh-axis context threaded through the model stack.

    Two execution regimes share the model code:

      * pjit path (dryrun / single-host): ctx is inactive — the model
        emits plain ops plus activation anchors and GSPMD partitions
        them from the pspec rules,
      * dist path (``--dist`` train step): the forward/backward runs
        INSIDE shard_map with params entering model-sharded per
        :func:`params_pspecs`; ctx tells each layer how to finish its
        row-parallel matmuls (psum over ``model_axis``), gather the
        embedding slice, and slice replicated vectors to the local
        feature block.

    All sharded/replicated decisions the model code makes from ctx are
    *static* (local-vs-global shape comparisons at trace time), so a
    single compiled executable serves every runtime straggler pattern.

    ``seq_shard`` adds the sequence-parallel regime (Megatron SP):
    between a row-parallel out-projection and the next column-parallel
    in-projection the activations live sharded along the *sequence*
    axis over ``model_axis`` — the row-parallel matmul finishes with a
    reduce-scatter (:meth:`psum_scatter`) instead of a full all-reduce,
    the norm/residual work in between runs on the local seq block
    (1/tp the activation bytes), and the in-projection re-gathers
    (:meth:`gather_seq`).  Collective bytes are identical (a ring
    all-reduce IS reduce-scatter + all-gather); only the live
    activation state shrinks.  The local-vs-global seq length is a
    static trace-time property of each array (``S_local = S // tp``),
    so SP preserves the one-executable / runtime-λ contract.
    """

    model_axis: str = MODEL_AXIS
    data_axes: Tuple[str, ...] = (POD_AXIS, DATA_AXIS)
    tp: int = 1
    inside_shard_map: bool = False
    seq_shard: bool = False
    # pipeline parallelism over the leading "stage" mesh axis: each
    # stage holds its contiguous block of n_groups // pp layer groups
    # (param leaves under "groups" shard their stacked leading dim) and
    # the dist train step drives a microbatched ppermute pipeline.
    stage_axis: str = STAGE_AXIS
    pp: int = 1

    @property
    def active(self) -> bool:
        return self.inside_shard_map and self.tp > 1

    @property
    def sp(self) -> bool:
        """Sequence-parallel regime on (TP active + seq sharding)."""
        return self.active and self.seq_shard

    @property
    def pp_active(self) -> bool:
        """Pipeline-parallel regime on (inside shard_map + stages)."""
        return self.inside_shard_map and self.pp > 1

    def stage_index(self):
        """This shard's pipeline-stage index (0 when PP is off)."""
        if not self.pp_active:
            return 0
        return lax.axis_index(self.stage_axis)

    def no_sp(self) -> "ShardCtx":
        """Context with sequence sharding off — for sub-stacks whose
        seq axis must stay whole (the whisper encoder: ``enc_len``
        need not divide tp, and cross-attention wants full K/V)."""
        if not self.seq_shard:
            return self
        return dataclasses.replace(self, seq_shard=False)

    def psum(self, x):
        """Finish a row-parallel matmul (partial sums → full value)."""
        if not self.active:
            return x
        return lax.psum(x, self.model_axis)

    def pmax(self, x):
        if not self.active:
            return x
        return lax.pmax(x, self.model_axis)

    def axis_index(self):
        if not self.active:
            return 0
        return lax.axis_index(self.model_axis)

    def all_gather(self, x, axis: int = -1):
        """Concatenate the per-shard blocks along ``axis`` (tiled)."""
        if not self.active:
            return x
        return lax.all_gather(
            x, self.model_axis, axis=axis % x.ndim, tiled=True
        )

    # ---- sequence-parallel helpers -----------------------------------
    def _seq_check(self, x, axis: int) -> int:
        if x.shape[axis] % self.tp:
            raise ValueError(
                f"sequence parallelism needs the seq dim (axis {axis}, "
                f"size {x.shape[axis]}) divisible by tp={self.tp}"
            )
        return x.shape[axis] // self.tp

    def gather_seq(self, x, axis: int = 1):
        """Local seq block → full sequence (all_gather over model).

        The start of every column-parallel in-projection region under
        SP; a no-op otherwise (``x`` is already full-length)."""
        if not self.sp:
            return x
        return lax.all_gather(x, self.model_axis, axis=axis, tiled=True)

    def scatter_seq(self, x, axis: int = 1):
        """Full-sequence *replicated* value → this shard's seq block.

        For values that are already complete on every shard (embedding
        output, an unsharded sublayer's result) — a static slice, no
        collective.  Partial sums must use :meth:`psum_scatter`."""
        if not self.sp:
            return x
        local = self._seq_check(x, axis)
        start = self.axis_index() * local
        return lax.dynamic_slice_in_dim(x, start, local, axis=axis)

    def psum_scatter(self, x, axis: int = 1):
        """Finish a row-parallel matmul.

        Plain TP: full all-reduce (== :meth:`psum`).  SP: reduce-
        scatter over the seq axis — same link bytes as the all-reduce,
        but the result (and everything until the next
        :meth:`gather_seq`) holds only the local seq block."""
        if not self.active:
            return x
        if not self.seq_shard:
            return lax.psum(x, self.model_axis)
        self._seq_check(x, axis)
        return lax.psum_scatter(
            x, self.model_axis, scatter_dimension=axis, tiled=True
        )

    def local_block(self, v, local: int, axis: int = -1):
        """This shard's feature block of a replicated array.

        No-op when ``v`` already has the local size on ``axis`` (the
        consuming weight was not model-sharded) — a static decision.
        """
        if not self.active or v.shape[axis] == local:
            return v
        start = self.axis_index() * local
        return lax.dynamic_slice_in_dim(v, start, local, axis=axis)


#: inactive context — the pjit/decode paths and all default callers
NULL_CTX = ShardCtx()


def make_shard_ctx(mesh: Mesh, *, seq_shard: bool = False) -> ShardCtx:
    """ShardCtx for code running inside a shard_map region on ``mesh``.

    ``seq_shard`` turns on the sequence-parallel regime (activations
    seq-sharded over "model" between the TP collective pairs); it only
    takes effect when the mesh has a model axis of size > 1.
    """
    tp = int(mesh.shape.get(MODEL_AXIS, 1))
    return ShardCtx(
        model_axis=MODEL_AXIS,
        data_axes=dp_axes(mesh),
        tp=tp,
        inside_shard_map=True,
        seq_shard=seq_shard,
        pp=int(mesh.shape.get(STAGE_AXIS, 1)),
    )


def model_axis_only(pspecs: PyTree) -> PyTree:
    """Project a spec tree onto the in-region axes (drop pod/data).

    These are the shard_map ``in_specs``/``out_specs`` of the dist
    train step: params enter model-sharded — and, under pipeline
    parallelism, stage-sharded on their stacked layer-group dim — (XLA
    materializes any FSDP gather at the region boundary) and
    replicated over pod/data.  Stage entries only exist on meshes that
    HAVE a stage axis, so the projection is unchanged for every
    non-pipelined caller.
    """

    def one(spec):
        ent = []
        for e in tuple(spec):
            axes = e if isinstance(e, tuple) else (e,)
            if MODEL_AXIS in axes:
                ent.append(MODEL_AXIS)
            elif STAGE_AXIS in axes:
                ent.append(STAGE_AXIS)
            else:
                ent.append(None)
        return P(*ent)

    return jax.tree.map(one, pspecs, is_leaf=_IS_SPEC)


def model_sharded_mask(pspecs: PyTree) -> PyTree:
    """True per leaf iff the spec shards it over the model axis.

    The dist step's gradient correction keys off this: inside shard_map
    each shard computes ``∂(Σ_shards φ_j)/∂(local copy)`` of its
    replicated objective, so model-sharded leaves divide by tp and
    replicated leaves psum over model then divide by tp.
    """

    def one(spec):
        for e in tuple(spec):
            axes = e if isinstance(e, tuple) else (e,)
            if MODEL_AXIS in axes:
                return True
        return False

    return jax.tree.map(one, pspecs, is_leaf=_IS_SPEC)


def seq_sharded_mask(pspecs: PyTree) -> PyTree:
    """Per-leaf gradient-correction mask of the sequence-parallel step.

    Same projection as :func:`model_sharded_mask` — and deliberately
    so: under SP the forward consumes replicated leaves (norm scales,
    biases, per-head vectors) on the LOCAL seq block only, so their
    per-shard grads are *seq-block partials* and the psum over "model"
    is load-bearing (it completes the token sum) rather than an
    average of redundant copies; but the *set* of leaves needing that
    psum is exactly the non-model-sharded ones, and the /tp factor
    from differentiating the model-replicated objective is unchanged.
    Kept as its own name so the SP step states which regime it
    corrects for (and so the rule can diverge without touching call
    sites if an SP-only layout ever needs it to).
    """
    return model_sharded_mask(pspecs)


def stage_sharded_mask(pspecs: PyTree) -> PyTree:
    """True per leaf iff the spec shards it over the stage axis.

    The pipelined step's gradient correction keys off this exactly like
    :func:`model_sharded_mask` does for TP: inside shard_map every
    stage's backward of the stage-replicated objective computes
    ``∂(Σ_stages φ_s)/∂(local copy)``, so stage-sharded leaves (the
    stacked layer groups — each stage only ever touches its own block)
    divide by pp, while stage-replicated leaves (embedding, head,
    norms, the rest layers) additionally hold only their own stage's
    paths and must psum over "stage" first.
    """

    def one(spec):
        for e in tuple(spec):
            axes = e if isinstance(e, tuple) else (e,)
            if STAGE_AXIS in axes:
                return True
        return False

    return jax.tree.map(one, pspecs, is_leaf=_IS_SPEC)


def validate_tp(cfg, tp: int) -> None:
    """Clear error (instead of a shape crash) for a bad ``--tp`` degree.

    Checks the arch config's divisibility constraints for real
    tensor-parallel execution.  KV heads are exempt: when ``n_kv_heads``
    does not divide, K/V projections replicate (Megatron-style GQA
    fallback) as long as the local Q heads still group evenly.
    """
    if tp <= 1:
        return
    errs = []
    kinds = set(cfg.block_pattern)
    if cfg.d_model % tp:
        errs.append(f"d_model={cfg.d_model} not divisible by tp={tp}")
    if kinds & {"global", "local"} or cfg.is_encdec:
        if cfg.n_heads % tp:
            errs.append(f"n_heads={cfg.n_heads} not divisible by tp={tp}")
        elif cfg.n_kv_heads % tp and tp % cfg.n_kv_heads:
            # replicated-KV fallback: each shard's Q block must sit
            # inside ONE KV group (tp a multiple of n_kv_heads), else
            # the per-shard Q→KV pairing cannot be made consistent
            errs.append(
                f"GQA: n_kv_heads={cfg.n_kv_heads} neither divides nor "
                f"is divided by tp={tp} — KV heads can neither shard "
                f"nor replicate consistently"
            )
    if cfg.d_ff > 0 and kinds != {"ssm"}:
        ffd = cfg.d_ff_dense or cfg.d_ff
        if ffd % tp:
            errs.append(f"d_ff={ffd} not divisible by tp={tp}")
    if "ssm" in kinds:
        nh = (cfg.expand * cfg.d_model) // cfg.ssm_head_dim
        if nh % tp:
            errs.append(f"ssm heads={nh} not divisible by tp={tp}")
    if "recurrent" in kinds:
        r = cfg.lru_width or cfg.d_model
        if r % tp:
            errs.append(f"lru_width={r} not divisible by tp={tp}")
    if errs:
        raise ValueError(
            f"{cfg.name}: tensor parallelism tp={tp} violates "
            f"divisibility constraints: " + "; ".join(errs)
        )


def validate_seq_shard(cfg, tp: int, seq_len: int) -> None:
    """Clear error (instead of a shape crash) for a bad ``--seq-shard``.

    Sequence parallelism scatters the (B, S, d) activations over the
    model axis between the TP collective pairs, so S must divide the
    TP degree.  Recurrent kinds (Mamba-2 SSD / RG-LRU) are legal but
    their scan is sequential in seq — those blocks gather the full
    sequence before scanning (only the norm/residual/projection work
    between blocks shards), which a warning makes explicit.
    """
    if tp <= 1:
        raise ValueError(
            f"{cfg.name}: --seq-shard requires tensor parallelism "
            f"(tp={tp}); sequence sharding rides the 'model' mesh axis"
        )
    if seq_len % tp:
        raise ValueError(
            f"{cfg.name}: sequence parallelism needs the sequence "
            f"length divisible by tp: seq_len={seq_len} % tp={tp} != 0"
        )
    rec = set(cfg.block_pattern) & {"ssm", "recurrent"}
    if rec:
        warnings.warn(
            f"{cfg.name}: {sorted(rec)} blocks scan sequentially over "
            f"seq — sequence parallelism falls back to "
            f"gather-before-scan there (norm/residual/projection work "
            f"between blocks still shards)",
            stacklevel=2,
        )


def stage_layer_ranges(cfg, pp: int) -> Tuple[Tuple[int, int], ...]:
    """Per-stage ``(first_layer, one_past_last)`` under pp stages.

    Stages split the SCANNED layer groups contiguously — stage s owns
    groups ``[s·G/pp, (s+1)·G/pp)`` with ``G = n_layers // P`` for a
    block pattern of period P, i.e. ``P·G/pp`` consecutive layers.
    The unscanned remainder layers (``n_layers % P``), the final norm
    and the unembed head ride the LAST stage; the embedding sits on the
    first (every stage holds a replicated copy — only stage 0's embed
    output enters the pipeline).
    """
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    gps = n_groups // max(pp, 1)
    ranges = [
        (s * gps * period, (s + 1) * gps * period) for s in range(pp)
    ]
    lo, hi = ranges[-1]
    ranges[-1] = (lo, hi + cfg.n_layers % period)
    return tuple(ranges)


def validate_pp(cfg, pp: int, *, microbatches: int = 0,
                batch_rows: int = 0) -> None:
    """Clear error (instead of a shape crash) for a bad ``--pp`` degree.

    Pipeline stages shard the stacked layer-group dim of the scanned
    params, so the group count ``n_layers // len(block_pattern)`` must
    divide evenly.  ``microbatches``/``batch_rows`` (when given) check
    the per-group coded batch splits into whole microbatches.
    """
    if pp <= 1:
        return
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    errs = []
    if n_groups % pp:
        errs.append(
            f"n_layers={cfg.n_layers} with block pattern period "
            f"{period} gives {n_groups} scanned layer groups, not "
            f"divisible by pp={pp} — each stage must own an equal "
            f"contiguous group block"
        )
    if microbatches > 0 and batch_rows > 0 and batch_rows % microbatches:
        errs.append(
            f"per-group batch of {batch_rows} rows not divisible by "
            f"microbatches={microbatches}"
        )
    if errs:
        raise ValueError(
            f"{cfg.name}: pipeline parallelism pp={pp} violates "
            f"divisibility constraints: " + "; ".join(errs)
        )


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch-sharding axes present in this mesh (pod before data)."""
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.shape)


# ----------------------------------------------------------------------
# divisibility fitting
# ----------------------------------------------------------------------
def fit_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Clamp one spec to ``shape`` on ``mesh``.

    Guarantees of the result: entry count == ndim, every named axis
    exists in the mesh, is used at most once across the spec, and its
    size product divides the corresponding dim.  Axes that violate any
    of these are dropped (⇒ replicated on that dim) — never an error.
    """
    entries = list(tuple(spec))[: len(shape)]
    entries += [None] * (len(shape) - len(entries))
    used: set = set()
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        size = 1
        for a in axes:
            if a not in mesh.shape or a in used:
                continue
            if dim % (size * mesh.shape[a]) == 0:
                keep.append(a)
                size *= mesh.shape[a]
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return P(*out)


def fit_pspecs(spec_tree: PyTree, abs_tree: PyTree, mesh: Mesh) -> PyTree:
    """Tree-wise :func:`fit_spec`; structures must match leaf-for-leaf."""
    return jax.tree.map(
        lambda a, s: fit_spec(s, a.shape, mesh),
        abs_tree,
        spec_tree,
        is_leaf=lambda x: x is None,
    )


def to_shardings(pspecs: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec tree → NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        pspecs,
        is_leaf=lambda x: _IS_SPEC(x) or x is None,
    )


# ----------------------------------------------------------------------
# parameter rules
# ----------------------------------------------------------------------
# column-parallel (shard the OUTPUT features over "model"): y = x @ W
_COL_PARALLEL = {
    "wq", "wk", "wv", "wg", "wu", "w1", "w_gate", "w_lin",
    "zproj", "xproj", "dtproj", "router", "ws_g", "ws_u",
}
# row-parallel (shard the INPUT features; output needs an all-reduce —
# the anchors re-shard right after / ShardCtx.psum in the dist path):
# y = x @ W with x model-sharded.  w_a/w_x (RG-LRU gates) are row-
# parallel: they consume the model-sharded recurrence width and the
# full pre-activations are restored by one psum, then re-sliced.
_ROW_PARALLEL = {"wo", "wd", "w2", "out_proj", "w_out", "ws_d",
                 "w_a", "w_x"}
# MoE expert-stacked weights (E, in, out): expert dim over the EP axis
_EXPERT = {"we_g", "we_u", "we_d"}
# depthwise-conv weights (K, channels): channels follow the col-parallel
# projection feeding them
_CONV_CHANNEL = {"conv_w", "conv_x_w"}
# head-granular weights: TP must not split a head (or KV group), so the
# model axis is dropped unless the HEAD count divides tp — replicated
# K/V is the Megatron GQA fallback, not an error
_HEAD_OF = {"wq": "q", "wo": "q", "wk": "kv", "wv": "kv"}


def _param_rule(
    path_keys: Tuple[str, ...],
    shape: Tuple[int, ...],
    *,
    fsdp: bool,
    tp: bool,
    fsdp_axis,
    tp_axis,
    moe_ep_axis,
) -> P:
    """Full-rank spec for one parameter leaf (leading dims → None).

    Only the trailing (functional) dims carry axes; stacked layer-group
    leading dims stay replicated so ``lax.scan`` slices cheaply.
    """
    name = path_keys[-1] if path_keys else ""
    nd = len(shape)
    ent = [None] * nd

    def set_at(i, ax):
        if ax is not None and -nd <= i < nd:
            ent[i % nd] = ax

    if name in _EXPERT and nd >= 3:
        set_at(-3, moe_ep_axis if tp else None)
        if fsdp and moe_ep_axis != fsdp_axis:
            set_at(-2, fsdp_axis)
    elif name in _COL_PARALLEL and nd >= 2:
        set_at(-1, tp_axis if tp else None)
        if fsdp:
            set_at(-2, fsdp_axis)
    elif name in _ROW_PARALLEL and nd >= 2:
        set_at(-2, tp_axis if tp else None)
        if fsdp:
            set_at(-1, fsdp_axis)
    elif name == "table" and nd >= 2:
        # embedding (V, d): d-sharded over model (all-gathered at the
        # use site — see models.transformer._embed), vocab over FSDP
        set_at(-1, tp_axis if tp else None)
        if fsdp:
            set_at(-2, fsdp_axis)
    elif name == "w" and nd >= 2:
        # unembed head (d, V): vocab-parallel logits
        set_at(-1, tp_axis if tp else None)
        if fsdp:
            set_at(-2, fsdp_axis)
    elif name in _CONV_CHANNEL and nd >= 2:
        set_at(-1, tp_axis if tp else None)
    # 1-D vectors (norm scales, biases, A_log, D, dt_bias, lam, conv_b)
    # stay replicated: tiny, and elementwise consumers resist resharding.
    return P(*ent)


def params_pspecs(
    params: PyTree,
    cfg,
    mesh: Mesh,
    *,
    fsdp: bool = True,
    mode: str = "2d",
    moe_ep_axis: str = MODEL_AXIS,
    head_aligned: bool = False,
) -> PyTree:
    """PartitionSpec tree for a parameter pytree.

    ``mode="2d"``: TP over "model" + FSDP over "data" (never "pod" — in
    the HGC mapping params are replicated per pod/edge).
    ``mode="dp_only"``: no tensor parallelism; FSDP spreads over the
    combined ("data", "model") axes instead so the whole mesh acts as
    one data-parallel farm.
    ``head_aligned``: only shard head-granular weights over "model" when
    whole heads divide the TP degree.  The explicit in-shard_map TP
    path REQUIRES this (a mid-head block cannot execute); the pjit path
    must NOT use it — GSPMD handles mid-head storage blocks fine, and
    dropping them there would replicate large weights for no reason.
    """
    if mode not in ("2d", "dp_only"):
        raise ValueError(f"unknown sharding mode {mode!r}")
    tp = mode == "2d"
    if tp:
        fsdp_axis: Any = DATA_AXIS
        tp_axis: Any = MODEL_AXIS
    else:
        fsdp_axis = tuple(
            a for a in (DATA_AXIS, MODEL_AXIS) if a in mesh.shape
        )
        tp_axis = None
    ep = moe_ep_axis if moe_ep_axis in mesh.shape else MODEL_AXIS
    tp_size = int(mesh.shape.get(MODEL_AXIS, 1))
    pp_size = int(mesh.shape.get(STAGE_AXIS, 1))
    ssm_heads = (
        (cfg.expand * cfg.d_model) // cfg.ssm_head_dim
        if getattr(cfg, "ssm_head_dim", 0) else 0
    )

    def head_ok(name: str) -> bool:
        """TP may only shard whole heads (attention) / SSM head blocks."""
        if not head_aligned or tp_size <= 1:
            return True
        if name in _HEAD_OF:
            heads = (cfg.n_heads if _HEAD_OF[name] == "q"
                     else cfg.n_kv_heads)
            return bool(heads) and heads % tp_size == 0
        if name in ("zproj", "xproj", "dtproj", "conv_x_w"):
            return bool(ssm_heads) and ssm_heads % tp_size == 0
        return True

    def rule(path, leaf):
        keys = tuple(
            k.key if hasattr(k, "key") else str(k) for k in path
        )
        name = keys[-1] if keys else ""
        leaf_tp = tp and head_ok(name)
        spec = _param_rule(
            keys, tuple(leaf.shape), fsdp=fsdp, tp=leaf_tp,
            fsdp_axis=fsdp_axis, tp_axis=tp_axis if leaf_tp else None,
            moe_ep_axis=ep,
        )
        # pipeline parallelism: the DECODER's stacked layer groups
        # shard their leading (n_groups) dim over "stage" — each stage
        # holds its contiguous group block.  The whisper encoder's
        # groups stay stage-replicated (keys[0] == "encoder"): every
        # stage runs the encoder on its own microbatch slices.
        if pp_size > 1 and keys and keys[0] == "groups" and leaf.ndim:
            ent = list(tuple(spec))
            ent[0] = STAGE_AXIS
            spec = P(*ent)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_state_pspecs(opt_state: PyTree, pspecs: PyTree) -> PyTree:
    """Optimizer-state specs derived from the parameter specs.

    Moments with a parameter's exact shape inherit its spec; adafactor's
    factored accumulators (``vr`` drops the last dim, ``vc`` the
    second-to-last) inherit the surviving entries; scalars replicate.
    """
    flat = {}
    for path, spec in jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=_IS_SPEC
    )[0]:
        keys = tuple(k.key if hasattr(k, "key") else str(k) for k in path)
        flat[keys] = spec

    def lookup(keys: Tuple[str, ...]) -> Optional[Tuple[P, str]]:
        """Match an opt-state path onto a param path.

        Opt trees wrap the params tree under a container key ("m", "v",
        "acc") and adafactor adds a trailing "vr"/"vc"/"v" selector.
        """
        trail = ""
        if keys and keys[-1] in ("vr", "vc") or (
            len(keys) > 1 and keys[-1] == "v" and keys[:-1] not in flat
        ):
            trail = keys[-1]
            keys = keys[:-1]
        for strip in (1, 0):
            cand = keys[strip:]
            if cand in flat:
                return flat[cand], trail
        return None

    def rule(path, leaf):
        keys = tuple(k.key if hasattr(k, "key") else str(k) for k in path)
        hit = lookup(keys)
        if hit is None:
            return P(*([None] * leaf.ndim))
        spec, trail = hit
        ent = list(tuple(spec))
        if trail == "vr":  # param shape minus last dim
            ent = ent[:-1]
        elif trail == "vc":  # param shape minus second-to-last dim
            ent = ent[:-2] + ent[-1:]
        ent = (ent + [None] * leaf.ndim)[: leaf.ndim]
        # dropping a dim can orphan a duplicate-free guarantee; re-check
        seen: set = set()
        clean = []
        for e in ent:
            axes = e if isinstance(e, tuple) else (e,)
            if e is not None and any(a in seen for a in axes):
                clean.append(None)
                continue
            seen.update(a for a in axes if a is not None)
            clean.append(e)
        return P(*clean)

    return jax.tree_util.tree_map_with_path(rule, opt_state)


def residual_pspecs(params: PyTree, cfg, mesh: Mesh, *,
                    fsdp: bool = True) -> PyTree:
    """EF-residual layout of the dist train step: per param leaf,
    ``P("pod", *model-axis entries of the param spec)``.

    Residual leaves are ``(n_pods, *param_shape)``; inside the step's
    shard_map each pod holds its own residual, sliced on the model axis
    exactly like the gradient leaf it telescopes against.
    """
    pspecs = fit_pspecs(
        params_pspecs(params, cfg, mesh, fsdp=fsdp, head_aligned=True),
        params, mesh,
    )
    mo = model_axis_only(pspecs)
    return jax.tree.map(
        lambda s: P(POD_AXIS, *tuple(s)), mo, is_leaf=_IS_SPEC
    )


def serve_shardings(
    params: PyTree,
    cache: PyTree,
    cfg,
    mesh: Mesh,
) -> Tuple[PyTree, PyTree]:
    """Fitted NamedSharding trees for the serving path on ``mesh``.

    Params follow the same Megatron TP rules as training but without
    FSDP (decode is latency-bound — gathering shards per token would
    dominate); the decode cache shards batch over (pod, data) and the
    fused head dim over "model".  GSPMD partitions the decode/prefill
    steps from these — the ShardCtx seam's inactive side, exactly how
    the dryrun decode cells lower.
    """
    pspecs = fit_pspecs(
        params_pspecs(params, cfg, mesh, fsdp=False), params, mesh
    )
    cspecs = fit_pspecs(cache_pspecs(cache, mesh), cache, mesh)
    return to_shardings(pspecs, mesh), to_shardings(cspecs, mesh)


def state_shardings(
    params: PyTree,
    opt_state: PyTree,
    cfg,
    mesh: Mesh,
    *,
    mode: str = "2d",
    fsdp: bool = True,
    head_aligned: bool = False,
) -> Tuple[PyTree, PyTree]:
    """Fitted NamedSharding trees for ``(params, opt_state)`` on ``mesh``.

    The one-call path the train driver uses: parameter rules →
    divisibility fit → optimizer-state inheritance → NamedShardings.
    The dist driver passes ``head_aligned=True`` so storage matches the
    step's in-shard_map TP layout exactly (no per-step re-shard).
    """
    pspecs = fit_pspecs(
        params_pspecs(params, cfg, mesh, fsdp=fsdp, mode=mode,
                      head_aligned=head_aligned),
        params, mesh,
    )
    ospecs = fit_pspecs(opt_state_pspecs(opt_state, pspecs), opt_state, mesh)
    return to_shardings(pspecs, mesh), to_shardings(ospecs, mesh)


# ----------------------------------------------------------------------
# batch / cache rules
# ----------------------------------------------------------------------
def batch_pspecs(cfg, mesh: Mesh) -> Dict[str, P]:
    """Input layouts: batch dim over (pod, data), features replicated."""
    dp = dp_axes(mesh)
    specs = {
        "tokens": P(dp, None),
        "targets": P(dp, None),
        "weights": P(dp, None),
        "denom": P(),
        "token": P(dp, None),
    }
    # M-RoPE positions (3, B, S): batch is axis 1
    specs["positions"] = P(None, dp, None)
    if getattr(cfg, "is_encdec", False):
        specs["enc_frames"] = P(dp, None, None)
    if getattr(cfg, "mrope_sections", ()):
        specs["visual_embeds"] = P(dp, None, None)
    return specs


def cache_pspecs(cache: PyTree, mesh: Mesh) -> PyTree:
    """Decode-cache layouts: batch over (pod, data), fused heads over
    "model".  Leaves under "groups" carry a stacked layer-group leading
    dim (scan) — their batch dim is axis 1, not 0."""
    dp = dp_axes(mesh)

    def rule(path, leaf):
        keys = tuple(k.key if hasattr(k, "key") else str(k) for k in path)
        nd = leaf.ndim
        if nd == 0 or "length" in keys:
            return P()
        stacked = "groups" in keys
        batch_dim = 1 if stacked and nd >= 2 else 0
        ent: list = [None] * nd
        ent[batch_dim] = dp
        # shard the fused feature dim (Kv·Dh / conv channels / d_state)
        if nd - batch_dim >= 3 and MODEL_AXIS in mesh.shape:
            ent[nd - 1] = MODEL_AXIS
        return P(*ent)

    return jax.tree_util.tree_map_with_path(rule, cache)


# ----------------------------------------------------------------------
# activation anchors
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _ActCtx:
    mesh: Mesh
    dp: Tuple[str, ...]
    tp: bool
    seq: bool = False


_ACT_CTX: Optional[_ActCtx] = None


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, dp=None, tp: bool = True,
                        seq: bool = False):
    """Enable the activation anchors for code traced inside this block.

    ``dp``: batch axes override (``dp_only`` passes ALL mesh axes so the
    model axis carries extra batch shards); default (pod, data).
    ``tp``: whether anchors pin the feature dim to "model".
    ``seq``: sequence-parallel layout instead — anchors pin the seq dim
    (axis 1) to "model" and leave the feature dim whole, the GSPMD
    counterpart of the ShardCtx ``seq_shard`` regime.
    """
    global _ACT_CTX
    prev = _ACT_CTX
    axes = tuple(dp) if dp is not None else dp_axes(mesh)
    _ACT_CTX = _ActCtx(mesh=mesh, dp=axes, tp=tp and not seq, seq=seq)
    try:
        yield
    finally:
        _ACT_CTX = prev


def _constrain(x, spec: P):
    ctx = _ACT_CTX
    if ctx is None:
        return x
    spec = fit_spec(spec, x.shape, ctx.mesh)
    return lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def anchor_activations(x):
    """(B, S, d) block outputs: batch over dp, d over model."""
    ctx = _ACT_CTX
    if ctx is None:
        return x
    ent = [None] * x.ndim
    if x.ndim >= 1:
        ent[0] = ctx.dp
    if ctx.seq and x.ndim >= 3:
        ent[1] = MODEL_AXIS  # sequence-parallel: seq over model
    elif ctx.tp and x.ndim >= 2:
        ent[-1] = MODEL_AXIS
    return _constrain(x, P(*ent))


def anchor_embed(x):
    """Post-embedding activations — same layout as block outputs."""
    return anchor_activations(x)


def anchor_logits(x):
    """(…, V) logits: batch over dp, vocab over model (vocab-parallel)."""
    return anchor_activations(x)


def anchor_replicated(x):
    """Force a full copy everywhere (the embed-table working copy)."""
    return _constrain(x, P(*([None] * x.ndim)))
