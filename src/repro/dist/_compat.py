"""JAX version compatibility shims for ``repro.dist``.

``shard_map`` graduated out of ``jax.experimental`` (``jax.shard_map``
from 0.5/0.6 onward) and its replication-check kwarg was renamed
``check_rep`` → ``check_vma``.  Every shard_map use in this repo goes
through :func:`shard_map` below so both the pinned ``jax<0.5`` CI leg
and the latest-``jax[cpu]`` leg run the same source.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.5.x
    from jax import shard_map as _shard_map_impl
except ImportError:  # the pinned 0.4.x toolchain
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = False):
    """``shard_map`` with the 0.4.x calling convention on any jax."""
    kw = {}
    if "check_rep" in _PARAMS:
        kw["check_rep"] = check_rep
    elif "check_vma" in _PARAMS:
        kw["check_vma"] = check_rep
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
    )


# Canonical backend probe lives with the kernels it gates
# (kernels sit below dist in the layer order, so the import is legal
# in exactly this direction); re-exported here so dist/launch callers
# keep their existing ``from repro.dist._compat import on_tpu``.
from repro.kernels.ops import on_tpu


__all__ = ["on_tpu", "shard_map"]
