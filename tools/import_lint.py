#!/usr/bin/env python
"""Import lint: the public-API boundary, enforced.

Rules (AST-level, no code execution):

  * ``examples/*.py`` may import from ``repro`` ONLY the public surface:
    ``repro.api`` (and submodules), ``repro.configs.*``, ``repro.data.*``.
  * ``tests/test_system.py`` (the black-box driver suite) must not
    import ``repro.launch`` internals — the CLI ``main`` entry points
    (``repro.launch.{train,serve,dryrun}.main``) are the only exception.

Exit 1 with a per-violation listing when the boundary leaks.
Run: python tools/import_lint.py   (from the repo root)
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

EXAMPLE_ALLOWED_PREFIXES = ("repro.api", "repro.configs", "repro.data")
CLI_MAINS = {"repro.launch.train", "repro.launch.serve",
             "repro.launch.dryrun"}


def _imports(path: Path):
    """Yield (module, names, lineno) for every import in the file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                yield a.name, None, node.lineno
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            yield (node.module or "",
                   [a.name for a in node.names], node.lineno)


def _is_allowed_example(mod: str) -> bool:
    if not (mod == "repro" or mod.startswith("repro.")):
        return True  # stdlib / third-party
    return any(mod == p or mod.startswith(p + ".")
               for p in EXAMPLE_ALLOWED_PREFIXES)


def _is_allowed_system_test(mod: str, names) -> bool:
    if not mod.startswith("repro.launch"):
        return True
    # `from repro.launch.train import main` — the CLI seam — is fine;
    # anything else (steps, hlo_analysis, mesh, …) is an internal leak.
    return mod in CLI_MAINS and names is not None and \
        set(names) <= {"main"}


def lint() -> int:
    violations = []
    for path in sorted((REPO / "examples").glob("*.py")):
        for mod, names, lineno in _imports(path):
            if not _is_allowed_example(mod):
                violations.append(
                    f"{path.relative_to(REPO)}:{lineno}: imports "
                    f"{mod!r} — examples may only use "
                    f"{', '.join(EXAMPLE_ALLOWED_PREFIXES)}"
                )
    sys_test = REPO / "tests" / "test_system.py"
    if sys_test.exists():
        for mod, names, lineno in _imports(sys_test):
            if not _is_allowed_system_test(mod, names):
                violations.append(
                    f"{sys_test.relative_to(REPO)}:{lineno}: imports "
                    f"{mod!r} — the black-box suite may only touch the "
                    f"CLI mains of repro.launch"
                )
    if violations:
        print("import-lint: the public-API boundary leaks:")
        for v in violations:
            print(" ", v)
        return 1
    print("import-lint: OK (examples + test_system stay on repro.api)")
    return 0


if __name__ == "__main__":
    sys.exit(lint())
