"""Planner-family Pareto sweep: expected iteration time vs per-worker
load vs straggler tolerance, across cluster shapes.

For every (cluster shape, planning strategy) pair this builds the
deployed scheme, prices it analytically (the order-statistic T̂ the
planners optimize), simulates its iteration-time distribution through
``sim.simulator``, and marks the non-dominated points per cluster on
the (T̂_sim, mean load, −tolerance) axes.  The headline acceptance
property — JNCSS weakly dominates the uncoded UniformPlanner on
heterogeneous clusters (no worse time, no less tolerance) — is asserted
here and recorded in the JSON for the CI gate.

``us_per_call`` (the regression-gated metric) times the three planner
solvers themselves on the paper's 4×10 cluster — pure CPU planning
cost, independent of the simulation sampling.

Set BENCH_PARETO_OUT to also write the JSON consumed by
``benchmarks.check_regression`` (the --quick harness does).
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from benchmarks.common import FAST, row, timeit
from repro.api.cluster import CodedCluster
from repro.core import comm_tradeoff, grouping, jncss
from repro.core.runtime_model import ClusterParams, paper_cluster
from repro.core.schemes import make_scheme
from repro.core.topology import Topology, Tolerance
from repro.dist.elastic import price_tolerance
from repro.sim.simulator import simulate_times

SIM_ITERS = 60 if FAST else 400

SCHEMES = ("uncoded", "hgc", "hgc_jncss", "hgc_grouped", "hgc_comm")


def _intra_hetero(n: int = 2, m: int = 8) -> ClusterParams:
    """Homogeneous edges, heterogeneous workers WITHIN the last edge
    (half its workers compute 5× slower with 10× heavier tails) — the
    regime where per-edge worker tolerances beat uniform ones."""
    base = CodedCluster.homogeneous(n, m).params
    c = base.c.copy()
    gamma = base.gamma.copy()
    off = (n - 1) * m + m // 2
    c[off:] *= 5.0
    gamma[off:] /= 10.0
    return dataclasses.replace(base, c=c, gamma=gamma)


def clusters():
    return (
        ("homog_2x4", CodedCluster.homogeneous(2, 4).params, 8),
        ("hetero_2x4", CodedCluster.hetero(2, 4).params, 8),
        ("intra_hetero_2x8", _intra_hetero(2, 8), 16),
        ("paper_4x10", paper_cluster("mnist"), 40),
    )


def _tolerance_nodes(topo: Topology, s_e: int, s_w_vec) -> float:
    """Tolerated node count: s_e edges + s_w^i workers per surviving
    edge (the tolerance axis of the front, higher = better)."""
    return float(s_e + (topo.n - s_e) * np.mean(s_w_vec))


def _analytic_T(params: ClusterParams, sch) -> float:
    """The order-statistic expected time the planners price."""
    code = getattr(sch, "code", None)
    if code is None:  # uncoded: tolerance (0,0) at load K/W
        return price_tolerance(params, Tolerance(0, 0), sch.load)
    if hasattr(code, "loads"):
        return grouping.price_grouped(params, code.tol, code.loads)
    return price_tolerance(params, code.tol, code.load)


def sweep_cluster(cname: str, params: ClusterParams, K: int):
    topo = params.topo
    points = []
    for scheme_name in SCHEMES:
        sch = make_scheme(scheme_name, topo, K, s_e=1, s_w=1,
                          params=params, seed=0)
        s_e = getattr(sch, "s_e", 0)
        s_w_vec = np.atleast_1d(getattr(sch, "s_w", 0))
        load_arr = np.atleast_1d(getattr(sch, "load_array", sch.load))
        t_sim = simulate_times(sch, params, SIM_ITERS, seed=0)
        points.append({
            "scheme": scheme_name,
            "s_e": int(s_e),
            "s_w": [int(s) for s in s_w_vec],
            "tolerance_nodes": _tolerance_nodes(topo, s_e, s_w_vec),
            "load_max": float(load_arr.max()),
            "load_mean": float(load_arr.mean()),
            "T_hat_ms": _analytic_T(params, sch),
            "T_sim_ms": float(t_sim.mean()),
            "master_msgs": int(sch.master_messages),
        })
    mask = comm_tradeoff.pareto_front([
        [p["T_sim_ms"], p["load_mean"], -p["tolerance_nodes"]]
        for p in points
    ])
    for p, keep in zip(points, mask):
        p["on_front"] = bool(keep)
        row(
            f"pareto/{cname}/{p['scheme']}",
            0.0,
            f"T_hat={p['T_hat_ms']:.0f}ms;T_sim={p['T_sim_ms']:.0f}ms;"
            f"load={p['load_mean']:.1f};tol={p['tolerance_nodes']:.1f};"
            f"front={int(p['on_front'])}",
        )
    return points


def _dominates(a, b) -> bool:
    """a weakly dominates b on (expected time ↓, tolerance ↑)."""
    return (a["T_hat_ms"] <= b["T_hat_ms"] + 1e-9
            and a["tolerance_nodes"] >= b["tolerance_nodes"] - 1e-9)


def main() -> None:
    fronts = {}
    hetero_ok = True
    for cname, params, K in clusters():
        points = sweep_cluster(cname, params, K)
        fronts[cname] = points
        by = {p["scheme"]: p for p in points}
        if cname != "homog_2x4":
            ok = _dominates(by["hgc_jncss"], by["uncoded"])
            hetero_ok = hetero_ok and ok
            row(f"pareto/{cname}/jncss_dominates_uniform", 0.0, ok)
            # grouped searches a superset of JNCSS's grid, so its
            # model-expected time can never be worse
            assert (by["hgc_grouped"]["T_hat_ms"]
                    <= by["hgc_jncss"]["T_hat_ms"] + 1e-9), cname
    assert hetero_ok, "JNCSS failed to dominate uncoded on a " \
        "heterogeneous cluster"

    # regression-gated metric: pure planner-solve cost on the 4×10
    # paper cluster (jncss grid + grouped per-edge argmin + budget scan)
    params = paper_cluster("mnist")

    def plan_all():
        jncss.solve(params, 40)
        grouping.plan_grouped(params, 40)
        comm_tradeoff.solve_comm_budget(
            params, 40, max_master_msgs=params.topo.n - 1
        )

    us = timeit(plan_all, repeats=3 if FAST else 10)
    row("pareto/planner_solve", us, "jncss+grouped+comm_budget")

    out = os.environ.get("BENCH_PARETO_OUT", "")
    if out:
        with open(out, "w") as f:
            json.dump({
                "name": "bench_pareto",
                "us_per_call": us,
                "sim_iters": SIM_ITERS,
                "jncss_weakly_dominates_uniform": hetero_ok,
                "fronts": fronts,
            }, f, indent=1)


if __name__ == "__main__":
    main()
