"""Analytic flops / bytes-moved models for the Pallas kernel family.

One record per family member at its representative benchmark shape.
Everything here is closed-form in the shapes — no timing, no HLO — so
``bench_kernels`` can emit a DETERMINISTIC ``us_per_call`` (the modeled
TPU roofline time ``max(flops/PEAK_FLOPS, bytes/HBM_BW)``) that
``check_regression`` gates meaningfully: the number moves only when a
kernel's payload layout or flop count changes (e.g. int4 un-packed back
to bytes), never because a CI runner was slow.  ``bench_roofline``
reuses the same records for the per-kernel arithmetic-intensity floors.
"""
from __future__ import annotations

from typing import Dict

from repro.api.aot import HBM_BW, PEAK_FLOPS

#: payload bytes per gradient value on the compressed hop
PAYLOAD_BYTES = {"int8": 1.0, "int4": 0.5, "fp8": 1.0}


def combine_model(R: int, K: int, F: int) -> Dict:
    """f32 ``coded_combine``: out (R,F) = C (R,K) @ G (K,F)."""
    flops = 2.0 * R * K * F
    bytes_ = 4.0 * (R * K + K * F + R * F)
    return _finish("coded_combine", flops, bytes_,
                   dict(R=R, K=K, F=F))


def combine_compressed_model(mode: str, R: int, K: int, F: int,
                             block: int) -> Dict:
    """Fused dequant combine: quantized G payload + f32 scales in,
    f32 out.  The dequant multiply (+ int4 unpack ops) ride the flop
    term; the byte term is what actually crosses HBM/the wire."""
    dequant = {"int8": 1.0, "int4": 4.0, "fp8": 1.0}[mode]  # ops/value
    flops = 2.0 * R * K * F + dequant * K * F
    bytes_ = (4.0 * R * K                      # coefficients
              + PAYLOAD_BYTES[mode] * K * F    # quantized payload
              + 4.0 * K * (F // block)         # per-block scales
              + 4.0 * R * F)                   # f32 out
    return _finish(f"coded_combine_{_SUFFIX[mode]}", flops, bytes_,
                   dict(R=R, K=K, F=F, block=block, mode=mode))


def decode_attention_model(B: int, C: int, Kv: int, G: int,
                           Dh: int) -> Dict:
    """Fused ring-buffer decode attention, one token: q·Kᵀ and p·V over
    the whole cache.  HBM sees q, the two caches, and out ONCE — the
    (H, C) score tensor never leaves VMEM (the point of the kernel)."""
    H = Kv * G
    flops = 4.0 * B * H * C * Dh  # 2·H·C·Dh for qk + same for pv
    bytes_ = (4.0 * B * H * Dh * 2        # q + out
              + 4.0 * B * C * Kv * Dh * 2)  # k + v cache, read once
    return _finish("decode_attention", flops, bytes_,
                   dict(B=B, C=C, Kv=Kv, G=G, Dh=Dh))


_SUFFIX = {"int8": "q", "int4": "q4", "fp8": "f8"}


def _finish(name: str, flops: float, bytes_: float, shape: Dict) -> Dict:
    intensity = flops / bytes_
    modeled_s = max(flops / PEAK_FLOPS, bytes_ / HBM_BW)
    return {
        "name": name,
        "shape": shape,
        "flops": flops,
        "bytes_moved": bytes_,
        "arithmetic_intensity": intensity,
        "modeled_us": modeled_s * 1e6,
        "bound": ("memory" if bytes_ / HBM_BW > flops / PEAK_FLOPS
                  else "compute"),
    }


# representative shapes: the fig-7-scale combine (R=8 rows, K=40 parts,
# 64k-value gradient slab, block 128) and a gemma3-27b-proportioned
# decode step (C=1024-slot ring, 8 kv heads x 4-way GQA, Dh=128)
BENCH_R, BENCH_K, BENCH_F, BENCH_BLOCK = 8, 40, 1 << 16, 128
DECODE_B, DECODE_C, DECODE_KV, DECODE_G, DECODE_DH = 8, 1024, 8, 4, 128


def family_records() -> Dict[str, Dict]:
    """The whole kernel family at its benchmark shapes, keyed by name."""
    recs = [
        combine_model(BENCH_R, BENCH_K, BENCH_F),
        combine_compressed_model("int8", BENCH_R, BENCH_K, BENCH_F,
                                 BENCH_BLOCK),
        combine_compressed_model("int4", BENCH_R, BENCH_K, BENCH_F,
                                 BENCH_BLOCK),
        combine_compressed_model("fp8", BENCH_R, BENCH_K, BENCH_F,
                                 BENCH_BLOCK),
        decode_attention_model(DECODE_B, DECODE_C, DECODE_KV, DECODE_G,
                               DECODE_DH),
    ]
    return {r["name"]: r for r in recs}


# arithmetic-intensity floors (flops per byte moved) at the benchmark
# shapes, set at ~half the modeled value so CI catches a payload-layout
# regression (unpacked int4, f32 scale spill, re-materialized scores)
# without tripping on a small model refinement.  Modeled values:
# combine 3.33, q 9.28, q4 15.02, f8 9.28, decode_attention 1.99.
INTENSITY_FLOORS = {
    "coded_combine": 1.6,
    "coded_combine_q": 4.6,
    "coded_combine_q4": 7.5,
    "coded_combine_f8": 4.6,
    "decode_attention": 1.0,
}
