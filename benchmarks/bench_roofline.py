"""Roofline table from the dry-run artifacts (deliverable g).

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and
emits one row per (arch × shape × mesh): the three roofline terms, the
dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs.  us_per_call reports
the projected step time = max(term)·1e6.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def main() -> None:
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        row("roofline/missing", 0.0,
            f"no dry-run artifacts under {RESULTS}; run "
            "`python -m repro.launch.dryrun --all --out results/dryrun`")
        return
    n_ok = n_skip = n_err = 0
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        tag = "multi" if rec.get("multi_pod") else "single"
        name = f"roofline/{rec['arch']}/{rec['shape']}/{tag}"
        if rec["status"] == "skipped":
            n_skip += 1
            row(name, 0.0, f"skipped:{rec['reason'][:60]}")
            continue
        if rec["status"] != "ok":
            n_err += 1
            row(name, 0.0, f"ERROR:{rec['error'][:80]}")
            continue
        n_ok += 1
        r = rec["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / step_s if step_s else 0.0
        row(
            name,
            step_s * 1e6,
            f"dominant={r['dominant'].replace('_s','')};"
            f"compute={r['compute_s']:.3f}s;memory={r['memory_s']:.3f}s;"
            f"collective={r['collective_s']:.3f}s;"
            f"useful_ratio={r['useful_flops_ratio']:.3f};"
            f"roofline_frac={frac:.3f}",
        )
    row("roofline/summary", 0.0, f"ok={n_ok};skipped={n_skip};err={n_err}")


if __name__ == "__main__":
    main()
