"""Roofline probe + table (deliverable g), wired into the baseline gate.

Two modes, both emitted on every run:

1. **Self-generated smoke probe** (always): a child process compiles
   the deepened llama3-family smoke train step on the 8-device
   (pod=2, data=2, model=2) test mesh and runs the HLO cost model
   (:mod:`repro.launch.hlo_analysis`) over the compiled module — no
   wall-clock timing anywhere.  The record carries the three roofline
   terms (TPU v5e constants from :mod:`repro.api.aot`), the dominant
   bottleneck, and the **arithmetic intensity** (flops per
   bf16-equivalent HBM byte), asserted against a floor: a change that
   bloats the step's memory traffic relative to its flops (a dropped
   fusion, an accidental f32 spill, remat gone wrong) fails the probe
   in CI rather than shipping green.  ``us_per_call`` is the projected
   step time ``max(term)·1e6`` — deterministic, so
   ``benchmarks.check_regression`` gates it against
   ``benchmarks/baselines/BENCH_roofline.json`` (a >tol increase means
   the compiled step's flops or bytes grew, not that a runner was
   slow).  When ``BENCH_ROOFLINE_OUT`` is set (``benchmarks.run
   --quick``) the record is written there as JSON.

2. **Per-kernel floors** (always): every member of the Pallas kernel
   family (coded_combine, the q8/q4/f8 fused-dequant variants, fused
   decode attention) gets its analytic arithmetic intensity from
   :mod:`benchmarks.kernel_models` asserted against a per-kernel floor
   (:data:`~benchmarks.kernel_models.INTENSITY_FLOORS`).  A payload
   regression — int4 shipped un-packed, scales spilled at f32 per
   value, decode scores re-materialized to HBM — halves a kernel's
   intensity and fails its floor in CI.

3. **Legacy artifact table** (when present): one row per
   results/dryrun/*.json produced by ``repro.launch.dryrun --all``.
"""
from __future__ import annotations

import glob
import json
import os
import subprocess
import sys

from benchmarks.common import row

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")

_CHILD_FLAG = "--child"

# flops per bf16-equivalent HBM byte of the smoke train step.  Measured
# ~5.3 on the probe config (remat'd flash step at S=512); the floor at
# half catches a step whose HBM traffic doubles relative to its flops.
INTENSITY_FLOOR = 2.5


def _child() -> None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses

    import jax
    import jax.numpy as jnp

    from benchmarks.common import FAST
    from repro.api.aot import HBM_BW, LINK_BW, PEAK_FLOPS
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.dist.mesh import make_test_mesh
    from repro.launch import hlo_analysis
    from repro.launch import steps as steps_lib
    from repro.optim import make_optimizer

    B, S = (8, 512) if FAST else (8, 1024)
    cfg = dataclasses.replace(
        get_smoke_config("llama3-8b"),
        n_layers=16, d_model=128, d_ff=256, head_dim=32,
        flash=True, remat_policy="save_block_outputs",
    )
    tcfg = TrainConfig(optimizer="sgd", lr=1e-2, total_steps=100,
                       warmup_steps=10, grad_clip=0.0)
    optimizer = make_optimizer("sgd")
    mesh = make_test_mesh(2, 2, 2)
    params_abs, opt_abs = steps_lib.abstract_state(cfg, tcfg)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "weights": jax.ShapeDtypeStruct((B, S), jnp.float32),
        "denom": jax.ShapeDtypeStruct((), jnp.float32),
    }
    lam_abs = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    step_fn = jax.jit(steps_lib._make_dist_train_step(
        cfg, tcfg, mesh, optimizer=optimizer))
    compiled = step_fn.lower(
        params_abs, opt_abs, batch_abs, lam_abs, {},
        jax.ShapeDtypeStruct((), jnp.int32),
    ).compile()
    ana = hlo_analysis.analysis_record(compiled.as_text(),
                                       pod_stride=10**9)
    compute_s = ana["flops"] / PEAK_FLOPS
    memory_s = ana["bytes_accessed_bf16eq"] / HBM_BW
    collective_s = ana["collective_link_bytes_bf16eq"] / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    intensity = ana["flops"] / max(ana["bytes_accessed_bf16eq"], 1.0)
    print(json.dumps({
        "name": "roofline_smoke",
        "us_per_call": max(terms.values()) * 1e6,
        "flops": ana["flops"],
        "bytes_accessed_bf16eq": ana["bytes_accessed_bf16eq"],
        "collective_link_bytes_bf16eq":
            ana["collective_link_bytes_bf16eq"],
        "arithmetic_intensity": intensity,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "batch": B,
        "seq_len": S,
        "mesh": "pod=2,data=2,model=2",
    }))


def _smoke_probe() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src") or "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_roofline", _CHILD_FLAG],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"roofline smoke probe failed:\n{r.stderr[-2000:]}"
        )
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    # the intensity floor: check_regression only gates the timed key,
    # so the flops-per-HBM-byte property is asserted here
    if rec["arithmetic_intensity"] < INTENSITY_FLOOR:
        raise RuntimeError(
            f"arithmetic intensity regressed: "
            f"{rec['arithmetic_intensity']:.2f} flops/byte < floor "
            f"{INTENSITY_FLOOR} (flops {rec['flops']:.3e}, bf16-eq "
            f"bytes {rec['bytes_accessed_bf16eq']:.3e}) — the step's "
            f"HBM traffic grew relative to its compute"
        )
    row(
        "roofline/smoke",
        rec["us_per_call"],
        f"dominant={rec['dominant'].replace('_s', '')};"
        f"intensity={rec['arithmetic_intensity']:.2f}flops/B;"
        f"compute={rec['compute_s'] * 1e3:.2f}ms;"
        f"memory={rec['memory_s'] * 1e3:.2f}ms;"
        f"collective={rec['collective_s'] * 1e3:.2f}ms",
    )
    out = os.environ.get("BENCH_ROOFLINE_OUT", "")
    if out:
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)


def _kernel_floors() -> None:
    """Per-kernel roofline rows + arithmetic-intensity floor gates."""
    from benchmarks.kernel_models import INTENSITY_FLOORS, family_records

    for name, rec in family_records().items():
        floor = INTENSITY_FLOORS[name]
        if rec["arithmetic_intensity"] < floor:
            raise RuntimeError(
                f"{name} arithmetic intensity regressed: "
                f"{rec['arithmetic_intensity']:.2f} flops/byte < floor "
                f"{floor} (flops {rec['flops']:.3e}, bytes "
                f"{rec['bytes_moved']:.3e}) — the kernel's payload "
                f"layout or data movement grew relative to its compute"
            )
        row(
            f"roofline/kernel/{name}",
            rec["modeled_us"],
            f"intensity={rec['arithmetic_intensity']:.2f}flops/B;"
            f"floor={floor};bound={rec['bound']};"
            f"bytes_moved={rec['bytes_moved']:.0f}",
        )


def main() -> None:
    _smoke_probe()
    _kernel_floors()
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        row("roofline/artifacts", 0.0,
            f"no dry-run artifacts under {RESULTS}; run "
            "`python -m repro.launch.dryrun --all --out results/dryrun` "
            "for the full arch x shape x mesh table")
        return
    n_ok = n_skip = n_err = 0
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        tag = "multi" if rec.get("multi_pod") else "single"
        name = f"roofline/{rec['arch']}/{rec['shape']}/{tag}"
        if rec["status"] == "skipped":
            n_skip += 1
            row(name, 0.0, f"skipped:{rec['reason'][:60]}")
            continue
        if rec["status"] != "ok":
            n_err += 1
            row(name, 0.0, f"ERROR:{rec['error'][:80]}")
            continue
        n_ok += 1
        r = rec["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / step_s if step_s else 0.0
        row(
            name,
            step_s * 1e6,
            f"dominant={r['dominant'].replace('_s','')};"
            f"compute={r['compute_s']:.3f}s;memory={r['memory_s']:.3f}s;"
            f"collective={r['collective_s']:.3f}s;"
            f"useful_ratio={r['useful_flops_ratio']:.3f};"
            f"roofline_frac={frac:.3f}",
        )
    row("roofline/summary", 0.0, f"ok={n_ok};skipped={n_skip};err={n_err}")


if __name__ == "__main__":
    if _CHILD_FLAG in sys.argv:
        _child()
    else:
        main()
