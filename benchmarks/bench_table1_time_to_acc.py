"""Table I: training time to reach target accuracy per scheme.

Paper headline numbers (MNIST): HGC up to 2.83× / 4.78× faster than
conventional-coded / Uncoded; HGC-JNCSS 1.64× over HGC.  Derived:
our measured speedups on the synthetic stand-in data.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FAST, FULL, row
from repro.core.runtime_model import paper_cluster
from repro.sim.simulator import simulate_training

SCHEMES = ("uncoded", "greedy", "cgc_w", "cgc_e", "standard_gc",
           "hgc", "hgc_jncss")


def main() -> None:
    params = paper_cluster("mnist")
    iters = 400 if FULL else 150
    target = 0.85
    times = {}
    for name in SCHEMES:
        tr = simulate_training(
            name, params, dataset="mnist", non_iid_level=1, K=40,
            iters=iters, eval_every=max(iters // 20, 1),
            n_data=8000 if FULL else 4000,
            batch_per_part=32 if FULL else 16, seed=11,
        )
        t = tr.time_to_accuracy(target)
        times[name] = t
        row(
            f"table1/mnist/{name}",
            float(np.mean(tr.iter_times_ms)) * 1e3,
            f"t_to_{target:.0%}={'%.3f h' % t if t else 'n/a'}",
        )
    if times.get("hgc") and times.get("uncoded"):
        conv = [times[n] for n in ("cgc_w", "cgc_e", "standard_gc")
                if times.get(n)]
        s_unc = times["uncoded"] / times["hgc"]
        s_conv = (min(conv) / times["hgc"]) if conv else float("nan")
        s_jn = (times["hgc"] / times["hgc_jncss"]
                if times.get("hgc_jncss") else float("nan"))
        row(
            "table1/mnist/speedups",
            0.0,
            f"hgc_vs_uncoded={s_unc:.2f}x;hgc_vs_conv={s_conv:.2f}x;"
            f"jncss_vs_hgc={s_jn:.2f}x",
        )


if __name__ == "__main__":
    main()
