"""Tensor-parallel train-step microbenchmark — the TP regression probe.

Times one step of ``steps.make_dist_train_step`` (llama3-family smoke
config) on the 8-device (pod=2, data=2, model=2) test mesh: real
in-shard_map TP — column/row-parallel matmuls, vocab-parallel CE, the
two-stage coded psum — end to end.  Because the device count must be
forced before jax initializes (and the bench harness may already have
initialized jax), the measurement always runs in a child process; the
parent emits the standard CSV row and, when ``BENCH_TRAINSTEP_TP_OUT``
is set (``benchmarks.run --quick``), the JSON record CI diffs against
``benchmarks/baselines/BENCH_trainstep_tp.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD_FLAG = "--child"


def _child() -> None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax
    import jax.numpy as jnp

    from benchmarks.common import FAST, timeit
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.data.pipeline import TokenStream
    from repro.dist.mesh import make_test_mesh
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tf
    from repro.optim import make_optimizer

    cfg = get_smoke_config("llama3-8b")
    tcfg = TrainConfig(
        optimizer="adamw", lr=1e-2, total_steps=100, warmup_steps=10,
        grad_clip=1.0,
    )
    optimizer = make_optimizer("adamw")
    mesh = make_test_mesh(2, 2, 2)
    step_fn = jax.jit(
        steps_lib.make_dist_train_step(cfg, tcfg, mesh, optimizer=optimizer)
    )
    B, S = (8, 32) if FAST else (16, 64)
    batch = {
        k: jnp.asarray(v)
        for k, v in TokenStream(cfg.vocab, B, S, seed=0).next_batch().items()
    }
    batch["denom"] = jnp.float32(B * S)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init(params)
    lam = jnp.full((2, 2), 0.25, jnp.float32)

    def run():
        _, _, _, metrics = step_fn(
            params, opt_state, batch, lam, {}, jnp.asarray(0)
        )
        jax.block_until_ready(metrics["loss"])

    us = min(timeit(run, repeats=10 if FAST else 20) for _ in range(3))
    print(json.dumps({
        "name": "trainstep_tp_smoke",
        "us_per_step": us,
        "batch": B,
        "seq_len": S,
        "mesh": "pod=2,data=2,model=2",
    }))


def main() -> None:
    if _CHILD_FLAG in sys.argv:
        _child()
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src") or "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_trainstep_tp", _CHILD_FLAG],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"TP train-step probe failed:\n{r.stderr[-2000:]}"
        )
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    print(f"{rec['name']},{rec['us_per_step']:.1f},"
          f"B{rec['batch']}xS{rec['seq_len']}@{rec['mesh']}")
    out = os.environ.get("BENCH_TRAINSTEP_TP_OUT", "")
    if out:
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
