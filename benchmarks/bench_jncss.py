"""Algorithm 2 (JNCSS): optimality vs brute force, runtime scaling to
1000+ node clusters (the vectorized form), Theorem 3 gap bound.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FAST, row, timeit
from repro.core import jncss
from repro.core.runtime_model import ClusterParams, paper_cluster
from repro.core.topology import Topology


def main() -> None:
    params = paper_cluster("mnist")
    res = jncss.solve(params, K=40)
    us = timeit(jncss.solve, params, 40, repeats=5)
    row(
        "jncss/paper_cluster",
        us,
        f"s_e={res.s_e};s_w={res.s_w};T={res.T_tol:.0f}ms;D={res.D:.0f}",
    )
    bound = jncss.theorem3_gap_bound(params, res, n_samples=1000)
    row("jncss/theorem3_bound", 0.0, f"gap_bound={bound:.0f}ms")

    # scaling: 1000+ node clusters (vectorized Algorithm 2)
    rng = np.random.default_rng(0)
    for n, m in ((8, 16), (16, 64), (32, 128)):
        topo = Topology.uniform(n, m)
        W = topo.total_workers
        big = ClusterParams(
            topo=topo,
            c=rng.uniform(5, 50, W),
            gamma=rng.uniform(0.01, 0.1, W),
            tau_w=rng.uniform(20, 100, W),
            p_w=rng.uniform(0.05, 0.5, W),
            tau_e=rng.uniform(50, 500, n),
            p_e=rng.uniform(0.05, 0.2, n),
        )
        t0 = time.perf_counter()
        r = jncss.solve(big, K=W)
        us = (time.perf_counter() - t0) * 1e6
        row(
            f"jncss/scale_{W}nodes",
            us,
            f"s_e={r.s_e};s_w={r.s_w};T={r.T_tol:.0f}ms",
        )


if __name__ == "__main__":
    main()
