"""CI regression gate: compare a benchmark JSON against its baseline.

    python -m benchmarks.check_regression CURRENT BASELINE [--tol 0.25]

Exits 1 when any timed metric is more than ``tol`` slower than the
committed baseline.  Speedups never fail; refresh the baseline by
copying a representative CI run's artifact over
``benchmarks/baselines/``.
"""
from __future__ import annotations

import argparse
import json
import sys

TIMED_KEYS = ("us_per_step", "us_per_call")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed slowdown fraction (0.25 = +25%%)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    name = cur.get("name", args.current)
    regressed = []
    compared = 0
    for key in TIMED_KEYS:
        if key not in cur or key not in base:
            continue
        compared += 1
        ratio = cur[key] / base[key]
        print(f"{name}.{key}: current {cur[key]:.1f} vs baseline "
              f"{base[key]:.1f}  ({ratio:.2f}x)")
        if ratio > 1.0 + args.tol:
            regressed.append(key)
    if compared == 0:
        # a renamed probe key / malformed baseline must not ship green
        print(f"ERROR: no timed keys {TIMED_KEYS} shared by "
              f"{args.current} and {args.baseline}")
        sys.exit(1)
    if regressed:
        print(f"REGRESSION: {regressed} exceed the {args.tol:.0%} budget")
        sys.exit(1)
    print("OK: within budget")


if __name__ == "__main__":
    main()
