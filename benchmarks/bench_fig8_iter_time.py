"""Fig. 8: average iteration time of each scheme vs number of parts K.

Paper claims: HGC up to 60.1% faster than conventional coded schemes
and 59.8% vs Uncoded; HGC-JNCSS up to 33.7% over HGC.  The derived
column reports our measured gains at each K.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FAST, row, timeit
from repro.core.runtime_model import paper_cluster
from repro.core.schemes import SCHEME_NAMES, make_scheme
from repro.sim.simulator import simulate_times


def main() -> None:
    params = paper_cluster("mnist")
    topo = params.topo
    iters = 100 if FAST else 300
    for K in (40, 80, 120, 160, 200):
        means = {}
        for name in SCHEME_NAMES:
            sch = make_scheme(name, topo, K, s_e=1, s_w=1, params=params)
            times = simulate_times(sch, params, iters, seed=K)
            means[name] = float(np.mean(times))
        conv_best = min(means["cgc_w"], means["cgc_e"],
                        means["standard_gc"])
        gain_conv = 1 - means["hgc"] / conv_best
        gain_unc = 1 - means["hgc"] / means["uncoded"]
        gain_jncss = 1 - means["hgc_jncss"] / means["hgc"]
        detail = ";".join(f"{k}={v:.0f}ms" for k, v in means.items())
        row(
            f"fig8/K={K}",
            means["hgc"] * 1e3,  # µs per simulated iteration
            f"hgc_vs_conv={gain_conv:.1%};hgc_vs_uncoded={gain_unc:.1%};"
            f"jncss_vs_hgc={gain_jncss:.1%};{detail}",
        )


if __name__ == "__main__":
    main()
