"""Fig. 7: communication loads of the master per scheme.

Proportional to the number of computation results the master receives
per iteration (paper §V-B).  Derived: messages and the reduction factor
vs Standard GC (the hierarchical pre-aggregation win the paper opens
with: ~10× for 100 workers / 10 edges).
"""
from __future__ import annotations

import time

from benchmarks.common import row
from repro.core.runtime_model import paper_cluster
from repro.core.schemes import SCHEME_NAMES, make_scheme


def main() -> None:
    params = paper_cluster("mnist")
    topo = params.topo
    K = 40
    t0 = time.perf_counter()
    loads = {
        name: make_scheme(name, topo, K, s_e=1, s_w=1,
                          params=params).master_messages
        for name in SCHEME_NAMES
    }
    us = (time.perf_counter() - t0) * 1e6 / len(loads)
    std = loads["standard_gc"]
    for name, msgs in loads.items():
        row(
            f"fig7/{name}",
            us,
            f"master_msgs={msgs};vs_standard_gc={std / msgs:.1f}x",
        )


if __name__ == "__main__":
    main()
