"""Fig. 7: communication loads of the master per scheme.

Proportional to the number of computation results the master receives
per iteration (paper §V-B).  Derived: messages and the reduction factor
vs Standard GC (the hierarchical pre-aggregation win the paper opens
with: ~10× for 100 workers / 10 edges).

Also emits the cross-pod BYTES per message under each wire codec
(f32 baseline vs int8 / int4 / fp8 blockwise quantization): the codec
reduction multiplies the hierarchical message reduction, so e.g. HGC +
int4 cuts master traffic by messages-ratio × ~8× in bytes.
"""
from __future__ import annotations

import time

from benchmarks.common import row
from repro.core.runtime_model import paper_cluster
from repro.core.schemes import SCHEME_NAMES, make_scheme
from repro.dist.compression import COMPRESSION_MODES, wire_bytes_per_value

# per-message payload values and quantization block of the codec hop
# (matches the kernel benchmark slab: F = 64k values, block = 128)
_F, _BLOCK = 1 << 16, 128


def main() -> None:
    params = paper_cluster("mnist")
    topo = params.topo
    K = 40
    t0 = time.perf_counter()
    loads = {
        name: make_scheme(name, topo, K, s_e=1, s_w=1,
                          params=params).master_messages
        for name in SCHEME_NAMES
    }
    us = (time.perf_counter() - t0) * 1e6 / len(loads)
    std = loads["standard_gc"]
    for name, msgs in loads.items():
        row(
            f"fig7/{name}",
            us,
            f"master_msgs={msgs};vs_standard_gc={std / msgs:.1f}x",
        )
    # codec byte reduction on the edge->master hop (per message of _F
    # values): f32 ships 4 B/value; each codec's wire cost includes its
    # per-block f32 scales, so the ratio is the honest end-to-end win
    hgc_msgs = loads["hgc"]
    for mode in COMPRESSION_MODES:
        bpv = wire_bytes_per_value(mode, _BLOCK)
        msg_bytes = bpv * _F
        row(
            f"fig7/bytes/{mode}",
            us,
            f"bytes_per_msg={msg_bytes:.0f};vs_f32={4.0 / bpv:.2f}x;"
            f"hgc_master_bytes={hgc_msgs * msg_bytes:.0f};"
            f"vs_standard_gc_f32="
            f"{std * 4.0 * _F / (hgc_msgs * msg_bytes):.1f}x",
        )


if __name__ == "__main__":
    main()
