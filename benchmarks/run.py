"""Benchmark harness entry point — one module per paper table/figure.

``python -m benchmarks.run`` prints ``name,us_per_call,derived`` CSV.
Set BENCH_FAST=1 for the reduced-iteration variant.

``--quick`` (the CI bench-smoke job) runs the fast subset with
BENCH_FAST=1 and writes the train-step probe as JSON (``--out``,
default BENCH_trainstep.json) for the regression gate
(``benchmarks.check_regression``).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

MODULES = [
    "benchmarks.bench_tradeoff",          # Thm 1 / Cor 1 load table
    "benchmarks.bench_fig7_comm_loads",   # Fig. 7
    "benchmarks.bench_fig8_iter_time",    # Fig. 8
    "benchmarks.bench_jncss",             # Alg 2 / Thm 2 / Thm 3
    "benchmarks.bench_kernels",           # Pallas microbench
    "benchmarks.bench_roofline",          # dry-run roofline table
    "benchmarks.bench_extensions",        # Cor. 2 multilayer + partial
    "benchmarks.bench_table1_time_to_acc",  # Table I
    "benchmarks.bench_fig56_accuracy",    # Figs. 5 & 6
    "benchmarks.bench_pareto",            # planner-family Pareto sweep
    "benchmarks.bench_trainstep",         # CI regression probe
    "benchmarks.bench_trainstep_tp",      # CI regression probe (dist TP)
    "benchmarks.bench_trainstep_sp",      # CI regression probe (seq-par)
    "benchmarks.bench_trainstep_pp",      # CI regression probe (pipeline)
    "benchmarks.bench_orchestrator",      # CI regression probe (service)
]

QUICK_MODULES = [
    "benchmarks.bench_tradeoff",
    "benchmarks.bench_jncss",
    "benchmarks.bench_pareto",
    "benchmarks.bench_trainstep",
    "benchmarks.bench_trainstep_tp",
    "benchmarks.bench_trainstep_sp",
    "benchmarks.bench_trainstep_pp",
    "benchmarks.bench_orchestrator",
    "benchmarks.bench_kernels",
    "benchmarks.bench_roofline",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fast subset with BENCH_FAST=1 (CI bench-smoke)")
    ap.add_argument("--out", default="BENCH_trainstep.json",
                    help="train-step JSON path (with --quick)")
    args = ap.parse_args(argv)
    modules = MODULES
    if args.quick:
        # set BEFORE the benchmark modules import benchmarks.common
        os.environ["BENCH_FAST"] = "1"
        os.environ["BENCH_TRAINSTEP_OUT"] = args.out
        root, ext = os.path.splitext(args.out)
        os.environ["BENCH_TRAINSTEP_TP_OUT"] = f"{root}_tp{ext or '.json'}"
        os.environ["BENCH_TRAINSTEP_SP_OUT"] = f"{root}_sp{ext or '.json'}"
        os.environ["BENCH_TRAINSTEP_PP_OUT"] = f"{root}_pp{ext or '.json'}"
        os.environ["BENCH_ORCHESTRATOR_OUT"] = os.path.join(
            os.path.dirname(args.out) or ".", "BENCH_orchestrator.json"
        )
        os.environ["BENCH_PARETO_OUT"] = os.path.join(
            os.path.dirname(args.out) or ".", "BENCH_pareto.json"
        )
        os.environ["BENCH_ROOFLINE_OUT"] = os.path.join(
            os.path.dirname(args.out) or ".", "BENCH_roofline.json"
        )
        os.environ["BENCH_KERNELS_OUT"] = os.path.join(
            os.path.dirname(args.out) or ".", "BENCH_kernels.json"
        )
        modules = QUICK_MODULES
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in modules:
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"{mod_name}/FAILED,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
