"""Pipeline-parallel train-step probe — per-device state + step time.

Measures ``steps._make_dist_train_step`` with the stage axis on
(stage=2, pod=2, data=2 — 8 devices) against the stage-less coded
baseline (pod=2, data=2), using a deepened llama3-family smoke config
(flash attention + ``save_block_outputs`` remat) whose stacked layer
groups — the arrays PP shards by pp× on the leading dim — dominate the
parameter tree.  Records both step times, the static schedule's bubble
fraction, and both compiled per-device state footprints
(``memory_analysis().argument_size_in_bytes`` — params + opt state +
batch as laid out on one device):

  * ``state_ratio = arg_bytes_base / arg_bytes_pp`` must stay ≥ ~1.4 at
    pp=2 (the point of pipeline parallelism: each stage holds only its
    own layer block),
  * ``bubble_frac = (pp-1)/(M+pp-1)`` is recorded so schedule changes
    show up in the artifact,
  * ``us_per_step`` (the PP step) is the timed key CI's
    ``check_regression`` gates against
    ``benchmarks/baselines/BENCH_trainstep_pp.json``.

Like the TP/SP probes, the measurement runs in a child process so the
forced host-device count precedes jax initialization; the parent emits
the CSV row and, when ``BENCH_TRAINSTEP_PP_OUT`` is set
(``benchmarks.run --quick``), the JSON record.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD_FLAG = "--child"

PP, MICRO = 2, 2


def _child() -> None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import FAST, timeit
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.dist.mesh import make_test_mesh
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tf
    from repro.optim import make_optimizer

    B, S = (8, 512) if FAST else (8, 1024)
    cfg = dataclasses.replace(
        get_smoke_config("llama3-8b"),
        n_layers=16, d_model=128, d_ff=256, head_dim=32,
        flash=True, remat_policy="save_block_outputs",
    )
    optimizer = make_optimizer("sgd")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "weights": jnp.ones((B, S), jnp.float32),
        "denom": jnp.float32(B * S),
    }
    lam = jnp.full((2, 2), 0.25, jnp.float32)

    def measure(pp: int):
        tcfg = TrainConfig(
            optimizer="sgd", lr=1e-2, total_steps=100, warmup_steps=10,
            grad_clip=0.0,
            pp_stages=pp, microbatches=MICRO if pp > 1 else 0,
        )
        mesh = make_test_mesh(2, 2, 1, stages=pp)
        step_fn = jax.jit(steps_lib._make_dist_train_step(
            cfg, tcfg, mesh, optimizer=optimizer))
        compiled = step_fn.lower(
            params, opt_state, batch, lam, {}, jnp.asarray(0)
        ).compile()
        ma = compiled.memory_analysis()
        args = int(ma.argument_size_in_bytes) if ma is not None else 0

        def run():
            _, _, _, metrics = step_fn(
                params, opt_state, batch, lam, {}, jnp.asarray(0)
            )
            jax.block_until_ready(metrics["loss"])

        us = min(timeit(run, repeats=3 if FAST else 5) for _ in range(2))
        return us, args

    base_us, base_bytes = measure(pp=1)
    pp_us, pp_bytes = measure(pp=PP)
    print(json.dumps({
        "name": "trainstep_pp_smoke",
        "us_per_step": pp_us,
        "base_us_per_step": base_us,
        "state_bytes_pp": pp_bytes,
        "state_bytes_base": base_bytes,
        "state_ratio": (base_bytes / pp_bytes) if pp_bytes else 0.0,
        "pp": PP,
        "microbatches": MICRO,
        "bubble_frac": (PP - 1) / (MICRO + PP - 1),
        "batch": B,
        "seq_len": S,
        "mesh": f"stage={PP},pod=2,data=2",
    }))


def main() -> None:
    if _CHILD_FLAG in sys.argv:
        _child()
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src") or "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_trainstep_pp", _CHILD_FLAG],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"PP train-step probe failed:\n{r.stderr[-2000:]}"
        )
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    # the point of PP: check_regression only gates the timed keys, so
    # the per-device state win (a deterministic compile-time metric —
    # each stage holds 1/pp of the stacked layer groups) is asserted
    # here; a silently stage-replicated param tree must fail the probe
    if rec["state_bytes_pp"] and rec["state_ratio"] < 1.4:
        raise RuntimeError(
            f"PP per-device state win regressed: state_ratio="
            f"{rec['state_ratio']:.2f}x (base {rec['state_bytes_base']} B "
            f"vs PP {rec['state_bytes_pp']} B), expected >= 1.4x"
        )
    print(f"{rec['name']},{rec['us_per_step']:.1f},"
          f"base={rec['base_us_per_step']:.1f}us "
          f"state_ratio={rec['state_ratio']:.2f}x "
          f"bubble={rec['bubble_frac']:.2f} "
          f"B{rec['batch']}xS{rec['seq_len']}@{rec['mesh']}")
    out = os.environ.get("BENCH_TRAINSTEP_PP_OUT", "")
    if out:
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
