"""Sequence-parallel train-step probe — activation memory + step time.

Measures ``steps.make_dist_train_step`` with ``seq_shard_activations``
on vs off (the TP baseline) on the 8-device (pod=2, data=2, model=2)
test mesh, using a deepened llama3-family smoke config (flash attention
+ ``save_block_outputs`` remat) where the remat-saved block outputs —
the buffers SP shrinks by tp× — dominate the live set.  Records both
step times and both compiled temp footprints
(``Compiled.memory_analysis().temp_size_in_bytes`` — the per-device
activation/workspace bytes of the step):

  * ``act_ratio = act_bytes_tp / act_bytes_sp`` must stay ≥ ~1.5 at
    tp=2 (the point of sequence parallelism),
  * ``us_per_step`` (the SP step) is the timed key CI's
    ``check_regression`` gates against
    ``benchmarks/baselines/BENCH_trainstep_sp.json``.

Like the TP probe, the measurement runs in a child process so the
forced host-device count precedes jax initialization; the parent emits
the CSV row and, when ``BENCH_TRAINSTEP_SP_OUT`` is set
(``benchmarks.run --quick``), the JSON record.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD_FLAG = "--child"


def _child() -> None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import FAST, timeit
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.dist.mesh import make_test_mesh
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tf
    from repro.optim import make_optimizer

    B, S = (8, 512) if FAST else (8, 1024)
    cfg = dataclasses.replace(
        get_smoke_config("llama3-8b"),
        n_layers=16, d_model=128, d_ff=256, head_dim=32,
        flash=True, remat_policy="save_block_outputs",
    )
    optimizer = make_optimizer("sgd")
    mesh = make_test_mesh(2, 2, 2)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "weights": jnp.ones((B, S), jnp.float32),
        "denom": jnp.float32(B * S),
    }
    lam = jnp.full((2, 2), 0.25, jnp.float32)

    def measure(seq_shard: bool):
        # grad_clip off: clipping adds a params-sized f32 workspace to
        # both regimes and only dilutes the activation-bytes signal
        tcfg = TrainConfig(
            optimizer="sgd", lr=1e-2, total_steps=100, warmup_steps=10,
            grad_clip=0.0, seq_shard_activations=seq_shard,
        )
        step_fn = jax.jit(steps_lib._make_dist_train_step(
            cfg, tcfg, mesh, optimizer=optimizer))
        compiled = step_fn.lower(
            params, opt_state, batch, lam, {}, jnp.asarray(0)
        ).compile()
        ma = compiled.memory_analysis()
        temp = int(ma.temp_size_in_bytes) if ma is not None else 0

        def run():
            _, _, _, metrics = step_fn(
                params, opt_state, batch, lam, {}, jnp.asarray(0)
            )
            jax.block_until_ready(metrics["loss"])

        us = min(timeit(run, repeats=3 if FAST else 5) for _ in range(2))
        return us, temp

    tp_us, tp_bytes = measure(seq_shard=False)
    sp_us, sp_bytes = measure(seq_shard=True)
    print(json.dumps({
        "name": "trainstep_sp_smoke",
        "us_per_step": sp_us,
        "tp_us_per_step": tp_us,
        "act_bytes_sp": sp_bytes,
        "act_bytes_tp": tp_bytes,
        "act_ratio": (tp_bytes / sp_bytes) if sp_bytes else 0.0,
        "batch": B,
        "seq_len": S,
        "mesh": "pod=2,data=2,model=2",
    }))


def main() -> None:
    if _CHILD_FLAG in sys.argv:
        _child()
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src") or "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_trainstep_sp", _CHILD_FLAG],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"SP train-step probe failed:\n{r.stderr[-2000:]}"
        )
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    # the point of SP: check_regression only gates the timed keys, so
    # the activation-byte win (a deterministic compile-time metric —
    # ~1.5x at tp=2) is asserted here; a silently-disabled seq_shard
    # path must fail the probe, not ship green
    if rec["act_bytes_sp"] and rec["act_ratio"] < 1.4:
        raise RuntimeError(
            f"SP activation-memory win regressed: act_ratio="
            f"{rec['act_ratio']:.2f}x (TP {rec['act_bytes_tp']} B vs "
            f"SP {rec['act_bytes_sp']} B), expected >= 1.4x"
        )
    print(f"{rec['name']},{rec['us_per_step']:.1f},"
          f"tp={rec['tp_us_per_step']:.1f}us "
          f"act_ratio={rec['act_ratio']:.2f}x "
          f"B{rec['batch']}xS{rec['seq_len']}@{rec['mesh']}")
    out = os.environ.get("BENCH_TRAINSTEP_SP_OUT", "")
    if out:
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
