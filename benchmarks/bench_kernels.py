"""Pallas coded_combine kernel microbenchmark (interpret mode on CPU —
timings are correctness-path numbers; the derived column also reports
the arithmetic intensity that drives the TPU roofline placement).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, row, timeit
from repro.kernels import ref
from repro.kernels.coded_combine import coded_combine


def main() -> None:
    rng = jax.random.PRNGKey(0)
    from benchmarks.common import FULL
    cases = [(8, 40, 1 << 14), (8, 40, 1 << 16)]
    if FULL:
        cases.append((16, 200, 1 << 18))
    for R, K, F in cases:
        k1, k2 = jax.random.split(rng)
        coeff = jax.random.normal(k1, (R, K), jnp.float32)
        grads = jax.random.normal(k2, (K, F), jnp.float32)

        def run_kernel():
            coded_combine(coeff, grads, interpret=True).block_until_ready()

        def run_ref():
            ref.coded_combine_ref(coeff, grads).block_until_ready()

        us_k = timeit(run_kernel, repeats=2)
        us_r = timeit(run_ref, repeats=2)
        flops = 2 * R * K * F
        bytes_ = 4 * (R * K + K * F + R * F)
        row(
            f"kernel/coded_combine_R{R}_K{K}_F{F}",
            us_k,
            f"ref_us={us_r:.0f};intensity={flops / bytes_:.2f}flop/B;"
            f"tpu_roofline_bound={'memory' if flops / bytes_ < 240 else 'compute'}",
        )


if __name__ == "__main__":
    main()
