"""Pallas kernel-family microbenchmark: one row per family member.

Each row's ``us_per_call`` is the DETERMINISTIC modeled TPU roofline
time ``max(flops/PEAK_FLOPS, bytes/HBM_BW)`` from
``benchmarks.kernel_models`` — it moves only when a kernel's payload
layout or flop count changes, so ``check_regression`` can gate it at a
tight tolerance on any CI runner.  The derived column carries the
bytes-moved, arithmetic intensity, roofline bound, and (info only) the
measured interpret-mode wall time, which exercises the real pallas_call
correctness path on CPU.

Set BENCH_KERNELS_OUT to also write the family as JSON with a
top-level ``us_per_call`` (sum of modeled times) for the CI gate.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, row, timeit
from benchmarks.kernel_models import family_records
from repro.kernels.coded_combine import (
    coded_combine,
    coded_combine_f8,
    coded_combine_q,
    coded_combine_q4,
)
from repro.kernels.decode_attention import decode_attention_fwd


def _combine_inputs(rng, R, K, F, block, mode):
    k1, k2, k3 = jax.random.split(rng, 3)
    coeff = jax.random.normal(k1, (R, K), jnp.float32)
    scales = jax.random.uniform(k3, (K, F // block), jnp.float32,
                                0.01, 1.0)
    if mode == "f32":
        return coeff, jax.random.normal(k2, (K, F), jnp.float32), None
    if mode == "int8":
        g = jax.random.randint(k2, (K, F), -127, 128, jnp.int8)
    elif mode == "int4":
        g = jax.random.randint(k2, (K, F // 2), -128, 128, jnp.int8)
    else:  # fp8
        g = jax.random.normal(k2, (K, F), jnp.float32).astype(
            jnp.float8_e4m3fn)
    return coeff, g, scales


def main() -> None:
    models = family_records()
    rng = jax.random.PRNGKey(0)
    # interpret mode is slow; shrink the measured shape when FAST while
    # keeping the MODELED us_per_call pinned to the benchmark shape
    meas_f = 1 << 12 if FAST else 1 << 14
    block = 128
    records = []

    runners = {}
    coeff, g, _ = _combine_inputs(rng, 8, 40, meas_f, block, "f32")
    runners["coded_combine"] = (
        lambda c=coeff, g=g: coded_combine(c, g, interpret=True)
        .block_until_ready())
    for mode, fn in (("int8", coded_combine_q), ("int4", coded_combine_q4),
                     ("fp8", coded_combine_f8)):
        c_, g_, s_ = _combine_inputs(rng, 8, 40, meas_f, block, mode)
        name = {"int8": "coded_combine_q", "int4": "coded_combine_q4",
                "fp8": "coded_combine_f8"}[mode]
        runners[name] = (
            lambda c=c_, g=g_, s=s_, f=fn: f(c, g, s, block=block,
                                             interpret=True)
            .block_until_ready())

    B, C, Kv, G, Dh = (1, 128, 2, 2, 64) if FAST else (2, 256, 4, 2, 64)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, 1, Kv * G, Dh), jnp.float32)
    kc = jax.random.normal(k2, (B, C, Kv, Dh), jnp.float32)
    vc = jax.random.normal(k3, (B, C, Kv, Dh), jnp.float32)
    runners["decode_attention"] = (
        lambda: decode_attention_fwd(q, kc, vc, 2 * C + 3,
                                     interpret=True)
        .block_until_ready())

    for name, model in models.items():
        us_interp = timeit(runners[name], repeats=2)
        row(
            f"kernel/{name}",
            model["modeled_us"],
            f"bytes_moved={model['bytes_moved']:.0f};"
            f"intensity={model['arithmetic_intensity']:.2f}flop/B;"
            f"bound={model['bound']};interp_us={us_interp:.0f}",
        )
        records.append(dict(model, interp_us=us_interp))

    out = os.environ.get("BENCH_KERNELS_OUT", "")
    if out:
        payload = {
            "name": "bench_kernels",
            # deterministic gate metric: modeled family total
            "us_per_call": sum(r["modeled_us"] for r in records),
            "kernels": {
                r["name"]: {
                    "us_per_call": r["modeled_us"],
                    "bytes_moved": r["bytes_moved"],
                    "arithmetic_intensity": r["arithmetic_intensity"],
                    "bound": r["bound"],
                    "shape": r["shape"],
                } for r in records
            },
        }
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
