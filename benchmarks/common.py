"""Shared benchmark utilities — timing + CSV row emission.

Every benchmark prints ``name,us_per_call,derived`` rows; ``derived``
carries the benchmark's headline quantity (a speedup, a load, a time).
"""
from __future__ import annotations

import os
import time
from typing import Callable

# default = budgeted iteration counts (completes in ~10 min on 1 CPU
# core); BENCH_FULL=1 restores the paper-scale iteration counts and
# BENCH_FAST=1 further trims for smoke runs.
FULL = os.environ.get("BENCH_FULL", "0") == "1"
FAST = os.environ.get("BENCH_FAST", "0") == "1" and not FULL


def row(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeats * 1e6
