"""Train-step microbenchmark — the CI regression gate's probe.

Times the jitted HGC train step (smoke llama3-family config, coded
per-example weights) and emits the standard CSV row.  When
``BENCH_TRAINSTEP_OUT`` is set (``benchmarks.run --quick`` does this)
the result is also written as JSON so CI can diff it against the
committed baseline in ``benchmarks/baselines/``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, row, timeit
from repro.configs.base import TrainConfig
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import TokenStream
from repro.launch import steps as steps_lib
from repro.models import transformer as tf
from repro.optim import make_optimizer


def main() -> None:
    cfg = get_smoke_config("llama3-8b")
    tcfg = TrainConfig(
        optimizer="adamw", lr=1e-2, total_steps=100, warmup_steps=10,
        grad_clip=1.0,
    )
    optimizer = make_optimizer("adamw")
    step_fn = jax.jit(
        steps_lib.make_train_step(cfg, tcfg, optimizer=optimizer)
    )
    B, S = (8, 32) if FAST else (16, 64)
    batch = {
        k: jnp.asarray(v)
        for k, v in TokenStream(cfg.vocab, B, S, seed=0).next_batch().items()
    }
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init(params)

    def run():
        _, _, metrics = step_fn(params, opt_state, batch, jnp.asarray(0))
        jax.block_until_ready(metrics["loss"])

    # best-of-3 means: a loaded CI runner inflates individual samples —
    # the minimum is the standard robust microbenchmark estimator
    us = min(
        timeit(run, repeats=10 if FAST else 20) for _ in range(3)
    )
    row("trainstep_smoke", us, f"B{B}xS{S}")
    out = os.environ.get("BENCH_TRAINSTEP_OUT", "")
    if out:
        with open(out, "w") as f:
            json.dump({
                "name": "trainstep_smoke",
                "us_per_step": us,
                "batch": B,
                "seq_len": S,
            }, f, indent=1)


if __name__ == "__main__":
    main()
