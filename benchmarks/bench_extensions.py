"""Beyond-paper extensions: Corollary 2 multilayer codes and the
partial-result (multi-message) speedup the paper cites as combinable.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, timeit
from repro.core import partial as P
from repro.core.hgc import HGCCode
from repro.core.multilayer import MultiLayerCode, TreeNode, \
    min_load_fraction
from repro.core.topology import Tolerance, Topology


def main() -> None:
    # 3-level (pod, host, chip) code — Corollary 2 constructed + decoded
    for branching, s in [((2, 2, 2), (1, 1, 1)), ((2, 4, 4), (1, 1, 2)),
                         ((2, 4, 8), (0, 1, 1))]:
        K = 16
        t0 = time.perf_counter()
        code = MultiLayerCode.build(TreeNode.uniform(branching), s, K=K)
        g = np.random.default_rng(0).normal(size=(K, 32))
        out = code.decode(g)
        us = (time.perf_counter() - t0) * 1e6
        err = float(np.max(np.abs(out - g.sum(0))))
        row(
            f"multilayer/{'x'.join(map(str, branching))}_s{s}",
            us,
            f"D={code.load};bound={float(min_load_fraction(branching, s)):.3f};"
            f"decode_err={err:.1e}",
        )

    # partial results: messages needed to decode vs full-result HGC
    code = HGCCode.build(Topology.uniform(3, 3), Tolerance(1, 1), K=9)
    D = code.load
    arrivals = [(j, t) for t in range(D) for j in range(3)]  # round-robin
    t0 = time.perf_counter()
    n_needed = P.earliest_decode_progress(code, 0, arrivals)
    us = (time.perf_counter() - t0) * 1e6
    full_equiv = (code.topo.m[0] - code.tol.s_w) * D
    row(
        "partial/roundrobin_3x3",
        us,
        f"messages_to_decode={n_needed};full_hgc_equivalent={full_equiv};"
        f"speedup={full_equiv / n_needed:.2f}x",
    )


if __name__ == "__main__":
    main()
