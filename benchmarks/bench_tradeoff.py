"""Theorem 1 / Corollary 1: computational-load table (paper §II-B).

Derived column: D_conventional / D_HGC load ratio at equal tolerance —
the paper's "fewer computational loads at the same straggler tolerance".
"""
from __future__ import annotations

import time

from benchmarks.common import row
from repro.core import tradeoff
from repro.core.topology import Tolerance, Topology


def main() -> None:
    cases = [
        ("example1_3x3", Topology.uniform(3, 3), Tolerance(1, 1)),
        ("paper_4x10_s11", Topology.uniform(4, 10), Tolerance(1, 1)),
        ("paper_4x10_s23", Topology.uniform(4, 10), Tolerance(2, 3)),
        ("hetero_4-6-8", Topology(m=(4, 6, 8)), Tolerance(1, 2)),
        ("wide_8x32", Topology.uniform(8, 32), Tolerance(3, 7)),
        ("pod_2x16", Topology.uniform(2, 16), Tolerance(1, 3)),
    ]
    for name, topo, tol in cases:
        t0 = time.perf_counter()
        hgc = tradeoff.min_load_fraction(topo, tol)
        conv = tradeoff.conventional_load_fraction(topo, tol)
        us = (time.perf_counter() - t0) * 1e6
        row(
            f"tradeoff/{name}",
            us,
            f"D_ratio={float(conv / hgc):.3f};hgc={float(hgc):.4f};"
            f"conv={float(conv):.4f}",
        )


if __name__ == "__main__":
    main()
