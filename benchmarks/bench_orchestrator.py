"""Orchestrator service probe — control-plane overhead + replan latency.

Runs one seeded kill + slow-edge episode through the full service stack
(worker pool → heartbeats → registry → fit-replan → ``external_step``)
on the thread backend and records:

  * ``us_per_step`` — real wall time per orchestrated round (pool
    dispatch/collect, completion-set selection, probe decode, the
    compiled train step, metrics emission).  This is the timed key CI's
    ``check_regression`` gates against
    ``benchmarks/baselines/BENCH_orchestrator.json``,
  * ``us_per_call`` — the heartbeat path alone (deliver every beat,
    evaluate deadlines, close the observation row), microbenchmarked
    over a registry of the same shape.  The second timed key: the
    monitor runs every round even when nothing fails, so its overhead
    must stay negligible next to a train step,
  * ``detect_to_replan_ms`` — VIRTUAL ms from the first liveness
    suspicion to the replan that prices it (deterministic on the seeded
    clock; recorded, not gated — it measures the deadline policy, not
    the implementation),
  * the episode's counters and ``jit_cache_entries`` so the artifact
    shows the zero-recompile invariant the parent asserts.

Like the train-step probes the episode runs in a child process so jax
initialization (and any forced platform flags) never leak into the
parent; the parent asserts the deterministic invariants — exactly one
compiled executable, at least one successful replan, every round
probe-decoded — prints the CSV row, and writes the JSON record when
``BENCH_ORCHESTRATOR_OUT`` is set (``benchmarks.run --quick``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD_FLAG = "--child"

N_EDGES, N_WORKERS = 3, 3
INJECT = "kill:w0.1@3,slow:e1@5x2:4.0"


def _heartbeat_microbench(repeats: int) -> float:
    """us per round of the pure control-plane heartbeat path."""
    import time

    from repro.core.topology import Topology
    from repro.orchestrator.heartbeat import (Heartbeat, HeartbeatConfig,
                                              HeartbeatMonitor)
    from repro.orchestrator.registry import DeviceRegistry

    topo = Topology((N_WORKERS,) * N_EDGES)
    registry = DeviceRegistry(topo)
    registry.register_all()
    monitor = HeartbeatMonitor(registry, HeartbeatConfig())
    W = topo.total_workers

    def round_of_beats(r: int) -> None:
        now = 100.0 * (r + 1)
        for flat in range(W):
            monitor.deliver(
                Heartbeat(flat=flat, sent_ms=now, runtime_ms=150.0),
                step=r)
        monitor.tick(r, now)
        monitor.record_round({f: 150.0 for f in range(W)})

    round_of_beats(0)  # warmup
    t0 = time.perf_counter()
    for r in range(1, repeats + 1):
        round_of_beats(r)
    return (time.perf_counter() - t0) / repeats * 1e6


def _child() -> None:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import time

    from benchmarks.common import FAST
    from repro.api import CodedCluster, CodedSession, FixedPlanner
    from repro.configs.registry import get_smoke_config
    from repro.orchestrator import (InjectionSchedule, MetricsSink,
                                    Orchestrator, OrchestratorConfig)

    steps = 8 if FAST else 12
    sess = CodedSession(
        CodedCluster.hetero(N_EDGES, N_WORKERS),
        get_smoke_config("llama3-8b"),
        planner=FixedPlanner(s_e=1, s_w=1), total_steps=steps + 4,
        mode="off", seed=0, verbose=False)
    metrics = MetricsSink()
    orch = Orchestrator(
        sess, OrchestratorConfig(steps=steps, backend="thread"),
        schedule=InjectionSchedule.parse(INJECT), metrics=metrics)
    t0 = time.perf_counter()
    summary = orch.run_episode()
    wall_us = (time.perf_counter() - t0) * 1e6

    hb_us = _heartbeat_microbench(repeats=50 if FAST else 200)
    iters = [r for r in metrics.records if r["record"] == "iteration"]
    print(json.dumps({
        "name": "orchestrator_episode",
        "us_per_step": wall_us / steps,
        "us_per_call": hb_us,
        "detect_to_replan_ms": summary.get("detect_to_replan_ms"),
        "episode_clock_ms": summary["episode_ms"],
        "jit_cache_entries": summary["jit_cache_entries"],
        "counters": summary["counters"],
        "decode_ok_rounds": sum(1 for r in iters if r["decode_ok"]),
        "steps": steps,
        "topology": f"{N_EDGES}x{N_WORKERS}",
        "inject": INJECT,
        "backend": "thread",
    }))


def main() -> None:
    if _CHILD_FLAG in sys.argv:
        _child()
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src") or "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_orchestrator", _CHILD_FLAG],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"orchestrator probe failed:\n{r.stderr[-2000:]}"
        )
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    # check_regression gates only the timed keys; the service-level
    # invariants are deterministic and asserted here — an episode that
    # recompiles, never replans, or mis-decodes must fail the probe
    # even if it got faster
    if rec["jit_cache_entries"] != 1:
        raise RuntimeError(
            f"orchestrated episode compiled {rec['jit_cache_entries']} "
            f"train executables, expected exactly 1"
        )
    if rec["counters"]["replans"] < 1:
        raise RuntimeError(
            "orchestrated episode never replanned — heartbeat detection "
            "or the fit-replan path is broken"
        )
    if rec["decode_ok_rounds"] != rec["steps"]:
        raise RuntimeError(
            f"probe decode failed on "
            f"{rec['steps'] - rec['decode_ok_rounds']} of "
            f"{rec['steps']} rounds"
        )
    if not (rec["detect_to_replan_ms"] and rec["detect_to_replan_ms"] > 0):
        raise RuntimeError(
            f"detect_to_replan_ms={rec['detect_to_replan_ms']} — the "
            f"episode's failure was never detected"
        )
    print(f"{rec['name']},{rec['us_per_step']:.1f},"
          f"hb={rec['us_per_call']:.1f}us "
          f"detect_to_replan={rec['detect_to_replan_ms']:.0f}ms "
          f"replans={rec['counters']['replans']} "
          f"{rec['topology']}@{rec['backend']}")
    out = os.environ.get("BENCH_ORCHESTRATOR_OUT", "")
    if out:
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
