"""dp_only sharding study: FSDP over ("data","model") vs pure replication.

ROADMAP open item: ``mode="dp_only"`` shards FSDP over the combined
("data", "model") axes while the batch anchors span all axes — is that
actually better than replicating the parameters outright?  This bench
answers with the dryrun machinery on the 512-chip production mesh:
lower + compile each variant and record XLA's memory analysis and the
collective traffic.  The verdict is static (no timing), so the record
is committed to ``benchmarks/baselines/BENCH_dp_only_fsdp.json`` as a
reference artifact rather than gated in CI (the 512-device compile is
too heavy for the bench-smoke job).

    PYTHONPATH=src python -m benchmarks.bench_dp_only_fsdp \
        [--arch mamba2-370m] [--out benchmarks/baselines/BENCH_dp_only_fsdp.json]

The child re-execs with the forced 512-device flag so the parent's jax
(if any) keeps its own device count.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_CHILD_FLAG = "--child"


def _child(arch: str, shape: str, microbatch: int) -> None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.launch.dryrun import run_cell

    out = {}
    for tag, fsdp in (("fsdp_data_model", True), ("replicated", False)):
        rec = run_cell(
            arch, shape, multi_pod=False, fsdp=fsdp,
            microbatch=microbatch, mode="dp_only", verbose=False,
        )
        keep = {
            k: rec.get(k)
            for k in ("status", "error", "lower_s", "compile_s",
                      "memory_analysis", "collective_operand_bytes",
                      "collective_link_bytes", "bytes_accessed")
        }
        out[tag] = keep
    print(json.dumps({
        "name": "dp_only_fsdp_vs_replicated",
        "arch": arch, "shape": shape, "mesh": "single-pod 16x16 (512 dev)",
        "microbatch": microbatch,
        "variants": out,
    }))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--microbatch", type=int, default=32)
    ap.add_argument("--out", default="")
    ap.add_argument("--child", action="store_true")
    args = ap.parse_args(argv)
    if args.child:
        _child(args.arch, args.shape, args.microbatch)
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src") or "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_dp_only_fsdp",
         "--arch", args.arch, "--shape", args.shape,
         "--microbatch", str(args.microbatch), _CHILD_FLAG],
        capture_output=True, text=True, env=env, timeout=3600,
    )
    if r.returncode != 0:
        raise RuntimeError(f"dp_only bench failed:\n{r.stderr[-3000:]}")
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    for tag, v in rec["variants"].items():
        mem = v.get("memory_analysis") or {}
        arg_gb = (mem.get("argument_bytes", 0) or 0) / 2**30
        tmp_gb = (mem.get("temp_bytes", 0) or 0) / 2**30
        print(f"{rec['name']}/{tag},0,"
              f"args {arg_gb:.3f} GiB/dev; temps {tmp_gb:.3f} GiB/dev; "
              f"coll {v.get('collective_link_bytes', 0):.3e} B")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
