"""Figs. 5 & 6: accuracy vs iterations and vs wall-clock per scheme.

Real training (logistic regression on the MNIST-like set; CNN on the
CIFAR-like set unless BENCH_FAST=1) with the schemes' actual gradient
aggregates and sampled iteration times.  Derived: final accuracy, total
simulated hours, and whether coded schemes match Uncoded accuracy while
Greedy degrades (the paper's qualitative claims).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FAST, FULL, row
from repro.core.runtime_model import paper_cluster
from repro.sim.simulator import simulate_training

SCHEMES = ("uncoded", "greedy", "cgc_w", "cgc_e", "standard_gc",
           "hgc", "hgc_jncss")


def run_dataset(dataset: str, non_iid: int, iters: int):
    params = paper_cluster(dataset)
    traces = {}
    for name in SCHEMES:
        tr = simulate_training(
            name, params, dataset=dataset, non_iid_level=non_iid,
            K=40, iters=iters, eval_every=max(iters // 10, 1),
            n_data=8000 if FULL else 4000,
            n_eval=1000 if FULL else 500,
            batch_per_part=32 if FULL else 16,
            seed=7,
        )
        traces[name] = tr
        row(
            f"fig56/{dataset}-L{non_iid}/{name}",
            float(np.mean(tr.iter_times_ms)) * 1e3,
            f"final_acc={tr.accuracies[-1]:.3f};"
            f"total_h={tr.total_time_h:.3f}",
        )
    # paper's qualitative checks
    coded_accs = [traces[n].accuracies[-1]
                  for n in ("cgc_w", "cgc_e", "standard_gc", "hgc",
                            "hgc_jncss")]
    unc = traces["uncoded"].accuracies[-1]
    ok_coded = all(a >= unc - 0.05 for a in coded_accs)
    greedy_gap = unc - traces["greedy"].accuracies[-1]
    row(
        f"fig56/{dataset}-L{non_iid}/claims",
        0.0,
        f"coded_match_uncoded={ok_coded};greedy_acc_gap={greedy_gap:.3f};"
        f"hgc_faster_than_uncoded="
        f"{traces['hgc'].total_time_h < traces['uncoded'].total_time_h}",
    )
    return traces


def main() -> None:
    iters = 400 if FULL else (120 if FAST else 150)
    for non_iid in (1, 3):
        run_dataset("mnist", non_iid, iters)
    if FULL:
        run_dataset("cifar", 1, 100)


if __name__ == "__main__":
    main()
