"""Reproduce the paper's §V evaluation (reduced): Figs. 5/6-style runs
of the paper's seven schemes — plus the grouped and message-budgeted
planners (docs/planners.md) — on the heterogeneous 4×10 cluster.

Run:  PYTHONPATH=src python examples/paper_simulation.py [--iters N]
"""
import argparse

import numpy as np

from repro.api import paper_cluster, simulate_training

SCHEMES = ("uncoded", "greedy", "cgc_w", "cgc_e", "standard_gc",
           "hgc", "hgc_jncss", "hgc_grouped", "hgc_comm")

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=150)
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "cifar"])
    ap.add_argument("--non-iid", type=int, default=1, choices=[1, 2, 3])
    args = ap.parse_args()

    params = paper_cluster(args.dataset)
    print(f"{'scheme':12s} {'mean iter':>10s} {'total':>8s} "
          f"{'final acc':>9s}")
    results = {}
    for name in SCHEMES:
        tr = simulate_training(
            name, params, dataset=args.dataset,
            non_iid_level=args.non_iid, iters=args.iters,
            eval_every=max(args.iters // 10, 1), n_data=4000,
            batch_per_part=16, seed=3,
        )
        results[name] = tr
        print(f"{name:12s} {np.mean(tr.iter_times_ms):8.0f} ms "
              f"{tr.total_time_h:7.3f}h {tr.accuracies[-1]:9.3f}")
    hgc, unc = results["hgc"], results["uncoded"]
    print(f"\nHGC finishes {unc.total_time_h / hgc.total_time_h:.2f}× "
          f"faster than Uncoded at matching accuracy "
          f"(paper: up to {4.78:.2f}× on MNIST time-to-accuracy)")
