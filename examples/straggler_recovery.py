"""Fault-tolerance demo: per-iteration straggler decode, checkpoint /
restart, and elastic replanning after a PERSISTENT edge failure — all
through the public `repro.api` surface.

Run:  PYTHONPATH=src python examples/straggler_recovery.py
"""
import numpy as np

from repro.api import ClusterParams, CodedCluster, Topology, replan

# ---- a heterogeneous 4-edge × 4-worker cluster --------------------------
# (JNCSS only pays for coding redundancy when nodes differ — Algorithm 2
# optimizes the expected-time proxy, and on a perfectly homogeneous
# cluster waiting for everyone is optimal in expectation.)
topo = Topology.uniform(4, 4)
W, n = topo.total_workers, topo.n
slow = np.tile([1.0, 1.0, 1.0, 5.0], n)  # one 5×-slower worker per edge
params = ClusterParams(
    topo=topo,
    c=10.0 * slow,
    gamma=np.where(slow > 1, 0.01, 0.05),
    tau_w=np.full(W, 50.0),
    p_w=np.where(slow > 1, 0.5, 0.1),
    tau_e=np.array([100.0, 100.0, 100.0, 500.0]),  # one weak edge
    p_e=np.array([0.1, 0.1, 0.1, 0.3]),
)
cluster = CodedCluster(params)
plan = replan(cluster.params, K=16)
code = plan.code
print(f"initial plan: (s_e={code.tol.s_e}, s_w={code.tol.s_w}), "
      f"K={code.K}, D={code.load}, T̂={plan.expected_iteration_ms:.0f} ms")

rng = np.random.default_rng(0)
g = rng.normal(size=(code.K, 8))
true = g.sum(0)

# ---- 1. transient stragglers: zero-cost recovery -----------------------
if code.tol.s_e >= 1:
    out = code.simulate_iteration(g, edge_stragglers=[3])
    print(f"transient edge-3 drop  → decode error "
          f"{np.max(np.abs(out - true)):.2e}  (no restart needed)")
else:
    print("JNCSS chose s_e=0 for this cluster "
          "(coding redundancy not worth it at these delays)")

# ---- 2. persistent failure: shrink + replan + resume --------------------
surviving = cluster.shrink(dead_edges=[3])
print(f"\nedge 3 died permanently → surviving topology "
      f"{surviving.topo.m} (record: dead_edges={list(surviving.dead_edges)})")
new_plan = replan(surviving.params, K=16)
print(f"replanned: (s_e={new_plan.tol.s_e}, s_w={new_plan.tol.s_w}), "
      f"K={new_plan.K}, D={new_plan.code.load}, "
      f"T̂={new_plan.expected_iteration_ms:.0f} ms")
g2 = np.concatenate([g, rng.normal(size=(new_plan.K - code.K, 8))])[: new_plan.K]
out = new_plan.code.simulate_iteration(g2[: new_plan.K])
print(f"post-replan decode error "
      f"{np.max(np.abs(out - g2[: new_plan.K].sum(0))):.2e}")
print("\nmodel/optimizer state is topology-independent — a checkpoint "
      "restore completes the recovery (CodedSession does the whole "
      "sequence in-loop: session.shrink(dead_edges=[3]); session.fit()).")
