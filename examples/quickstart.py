"""Quickstart: the paper's pipeline in 60 lines, through `repro.api`.

  1. a hierarchical cluster (3 edges × 3 workers — paper Example 1),
  2. the HGC two-layer code at tolerance (s_e=1, s_w=1),
  3. exact gradient recovery under stragglers,
  4. JNCSS picking the optimal tolerance for a heterogeneous cluster,
  5. the same system as ONE object: CodedCluster → CodedSession.fit().

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import (
    CodedCluster,
    CodedSession,
    HGCCode,
    Tolerance,
    Topology,
    jncss,
    paper_cluster,
    tradeoff,
)
from repro.configs.registry import get_smoke_config

# ---- 1. topology & tolerance (paper Example 1) -------------------------
topo = Topology.uniform(3, 3)
tol = Tolerance(s_e=1, s_w=1)
print(f"cluster: {topo.n} edges × {topo.m[0]} workers")
print(f"Theorem 1 load bound D/K ≥ {tradeoff.min_load_fraction(topo, tol)}")
print(f"conventional coding needs  {tradeoff.conventional_load_fraction(topo, tol)}")

# ---- 2. build the two-layer code ---------------------------------------
code = HGCCode.build(topo, tol, K=9, seed=0)
print(f"\nHGC code built: K={code.K} parts, per-worker load D={code.load} "
      f"(matches the bound with equality)")
print("worker (0,0) computes parts", code.assignment.worker_parts(0, 0))

# ---- 3. exact recovery under stragglers --------------------------------
rng = np.random.default_rng(0)
g_parts = rng.normal(size=(code.K, 6))  # 9 part-gradients, dim 6
true_grad = g_parts.sum(axis=0)

# edge 2 and one worker in each surviving edge straggle:
decoded = code.simulate_iteration(
    g_parts, edge_stragglers=[2], worker_stragglers=[[1], [0], []]
)
print(f"\nstragglers: edge 2 down, workers (0,1) and (1,0) down")
print(f"max |decoded − true| = {np.max(np.abs(decoded - true_grad)):.2e}")

# ---- 4. JNCSS on the paper's heterogeneous cluster ---------------------
params = paper_cluster("mnist")
res = jncss.solve(params, K=40)
print(f"\nJNCSS on the paper's 4×10 heterogeneous cluster:")
print(f"  optimal tolerance (s_e={res.s_e}, s_w={res.s_w}), "
      f"load D={res.D:.0f}, expected iteration {res.T_tol:.0f} ms")
print(f"  Theorem 3 gap bound: "
      f"{jncss.theorem3_gap_bound(params, res, n_samples=500):.0f} ms")

# ---- 5. the whole system as one object ---------------------------------
# CodedCluster (topology + runtime model + detector) + CodedSession
# (planner, compiled steps, elastic replan loop, checkpoints):
cluster = CodedCluster.hetero(n_edges=2, n_workers=4)
session = CodedSession(cluster, get_smoke_config("llama3-8b"),
                       planner="jncss", total_steps=4, seq_len=16,
                       log_every=2)
session.fit()
print(f"coded training over {cluster!r}: "
      f"final loss {session.losses[-1]:.4f}")
