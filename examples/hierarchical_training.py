"""End-to-end driver: train a transformer under HGC coded aggregation.

The 5-line public-API path: a `CodedCluster` (topology + runtime
model), a planner strategy, and a `CodedSession` that owns the mesh,
the compiled coded train step, JNCSS replanning and checkpoints.  The
reduced llama3-family config runs a few hundred steps on CPU; pass
--full on a TPU cluster for the real 8B config.

Run:  PYTHONPATH=src python examples/hierarchical_training.py [--steps N]
"""
import argparse

from repro.api import CodedCluster, CodedSession
from repro.configs.registry import get_config, get_smoke_config

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_hgc_ckpt")
    ap.add_argument("--dist", default="off",
                    choices=["off", "coded", "coded_int8"],
                    help="run the mesh-aware coded-collective loop "
                         "(needs n_edges × n_workers devices, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()

    cfg = (get_config if args.full else get_smoke_config)(args.arch)
    cluster = CodedCluster.homogeneous(n_edges=2, n_workers=4)
    session = CodedSession(
        cluster, cfg,
        planner="jncss", mode=args.dist,
        seq_len=64, total_steps=args.steps,
        checkpoint_dir=args.checkpoint_dir, checkpoint_every=50,
        resume=True,
    )
    session.fit(replan_every=100)
