"""End-to-end driver: train a transformer under HGC coded aggregation.

Wraps the production driver (repro.launch.train) — JNCSS planning,
coded per-example weights, straggler sampling, checkpoints, elastic
replanning.  The reduced llama3-family config runs a few hundred steps
on CPU; pass --full on a TPU cluster for the real 8B config.

Run:  PYTHONPATH=src python examples/hierarchical_training.py [--steps N]
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_hgc_ckpt")
    ap.add_argument("--dist", default="off",
                    choices=["off", "coded", "coded_int8"],
                    help="run the mesh-aware coded-collective loop "
                         "(needs n_edges × n_workers devices, e.g. "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()

    argv = [
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--scheme", "hgc_jncss",
        "--n-edges", "2", "--n-workers", "4",
        "--seq-len", "64",
        "--dist", args.dist,
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "50",
        "--replan-every", "100",
        "--resume",
    ]
    if not args.full:
        argv.append("--smoke")
    train_main(argv)
